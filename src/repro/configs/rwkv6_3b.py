"""rwkv6-3b — RWKV-6 "Finch" 3B [arXiv:2404.05892; hf].

32L d_model=2560 (attention-free, data-dependent decay) d_ff=8960
vocab=65536.  Sub-quadratic: runs the long_500k cell.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,              # d_model / rwkv_head_size
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    rwkv_head_size=64,
    gated_mlp=False,         # RWKV channel-mix is its own structure
    act="relu2",
    norm="layer",
    subquadratic=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=16, rwkv_head_size=16, d_ff=128,
                          vocab_size=512, remat=False)
