"""Config schema: model architecture + input-shape + run configuration.

One ModelConfig per assigned architecture lives in repro/configs/<arch>.py;
each also provides a reduced `smoke()` config of the same family for CPU
tests.  Shapes are the four assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.engine.spec import QuantSpec

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 128) -> int:
    """Round vocab up for MXU alignment and clean mesh divisibility."""
    return -(-v // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int                # raw (pre-padding) vocabulary
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_shard: str = "expert"      # 'expert' (EP) or 'mlp' (TP over d_ff)
    moe_dispatch_groups: int = 1   # >1: DP-shard-local dispatch (no gathers)
    router_aux_coef: float = 0.01
    # --- RWKV / SSM ---
    rwkv_head_size: int = 0
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    # --- encoder-decoder ---
    n_encoder_layers: int = 0
    # --- frontends (modality stubs: precomputed embeddings) ---
    frontend: Optional[str] = None  # 'vision' | 'audio'
    frontend_tokens: int = 0        # patches / frames per example
    # --- layer details ---
    qkv_bias: bool = False
    act: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 1e4
    norm: str = "rms"              # rms | layer
    tie_embeddings: bool = False
    logit_softcap: float = 0.0     # grok-style tanh soft capping
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"   # bf16 moments for the huge models
    attn_chunk: int = 2048         # switch to flash-chunked above this seq
    remat: bool = True
    scan_unroll: int = 1           # layer-scan unroll (dry-run cost variants)
    quant_planes: int = 0          # >0: BW-decomposed int8 linear path
    # full quantized-GEMM configuration; None defers to the quant_planes
    # sugar above (launchers materialize an explicit spec at startup so
    # concurrent engines with different specs never interfere)
    quant: Optional[QuantSpec] = None
    # --- parallelism policy ---
    fsdp: bool = True
    fsdp_over_pod: bool = False    # shard weights over the pod axis too
    # long-context support (sub-quadratic sequence mixing)
    subquadratic: bool = False

    def quant_spec(self) -> Optional[QuantSpec]:
        """The QuantSpec the model layers should execute under.

        An explicit ``quant`` field wins; otherwise the legacy
        ``quant_planes`` int is sugar for a default-grid spec whose impl
        comes from the deprecated global shim (preserving the old
        global-switch semantics for un-migrated callers).  Returns None
        when quantization is disabled.
        """
        if self.quant is not None:
            return self.quant if self.quant.enabled else None
        if self.quant_planes:
            from repro.engine import _compat
            return QuantSpec(planes=self.quant_planes,
                             impl=_compat.default_impl())
        return None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.family == "rwkv":
            attn = 5 * d * d + d * d  # r,k,v,w(g) projections + out
        mlp_mats = 3 if self.gated_mlp else 2
        mlp = mlp_mats * d * self.d_ff
        if self.n_experts:
            mlp = mlp * self.n_experts + d * self.n_experts
        block = attn + mlp
        n_blocks = self.n_layers + self.n_encoder_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return n_blocks * block + emb

    def active_param_count(self) -> int:
        if not self.n_experts:
            return self.param_count()
        dense_like = self.replace(n_experts=0, d_ff=self.d_ff *
                                  self.experts_per_token)
        return dense_like.param_count()


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
