"""minicpm-2b — MiniCPM 2.4B [arXiv:2404.06395; hf].

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753, llama-like blocks,
tied embeddings.  The paper's WSD (warmup-stable-decay) LR schedule is a
first-class option in repro.train.optimizer and is this arch's default.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    tie_embeddings=True,
    act="silu",
    gated_mlp=True,
    norm="rms",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=512, remat=False)
