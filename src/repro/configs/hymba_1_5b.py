"""hymba-1.5b — NVIDIA Hymba hybrid-head model [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16;
attention heads and a selective-SSM branch run in parallel per block and
fuse.  Sliding-window attention (W=2048) + 128 meta tokens make it
sub-quadratic end-to-end -> runs the long_500k cell.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    act="silu",
    gated_mlp=True,
    norm="rms",
    subquadratic=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=512, ssm_state=4,
                          remat=False)
