"""qwen1.5-110b — Qwen1.5 110B [hf:Qwen/Qwen1.5-0.5B; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, QKV bias.
Largest dense cell; bf16 optimizer moments + FSDP over the pod axis keep
per-chip state within HBM at 512 chips.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    act="silu",
    gated_mlp=True,
    norm="rms",
    opt_state_dtype="bfloat16",
    fsdp_over_pod=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=512, remat=False)
