"""grok-1-314b — xAI Grok-1 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts
top-2, tanh logit soft-capping.  Only 8 experts -> the expert FFNs are
tensor-parallel over d_ff ('mlp' shard) instead of expert-parallel; with
bf16 optimizer moments so (params + opt state + grads) fit 16 GB/chip at
512 chips (see EXPERIMENTS.md §Dry-run).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    experts_per_token=2,
    moe_shard="mlp",
    logit_softcap=30.0,
    act="gelu",
    gated_mlp=False,
    norm="rms",
    opt_state_dtype="bfloat16",
    fsdp_over_pod=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=512, n_experts=4,
                          experts_per_token=2, remat=False)
