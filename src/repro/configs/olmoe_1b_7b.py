"""olmoe-1b-7b — OLMoE 1B-active / 7B-total [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64 experts
top-8.  Experts sharded over the 'model' axis (expert parallelism).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    n_experts=64,
    experts_per_token=8,
    moe_shard="expert",
    act="silu",
    gated_mlp=True,
    norm="rms",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=16, d_ff=32, vocab_size=512, n_experts=4,
                          experts_per_token=2, remat=False)
