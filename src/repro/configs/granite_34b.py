"""granite-34b — IBM Granite 34B Code [arXiv:2405.04324; hf].

88L d_model=6144 48H MQA (kv=1) d_ff=24576 vocab=49152, llama-style
blocks.  kv=1 -> KV projections/caches replicated over the model axis
(sharding a size-1 head axis would only pad); the deepest assigned arch.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    act="silu",
    gated_mlp=True,
    norm="rms",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                          head_dim=16, d_ff=128, vocab_size=512, remat=False)
