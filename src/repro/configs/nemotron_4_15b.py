"""nemotron-4-15b — Nemotron-4 15B [arXiv:2402.16819; unverified].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU MLP
(non-gated), LayerNorm.  Squared-ReLU keeps the MLP activations
non-negative -- one sign-free operand improves EN-T digit sparsity for the
paper's quantized path (DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    head_dim=128,
    act="relu2",
    gated_mlp=False,
    norm="layer",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=512, remat=False)
