"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend stub
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.  The CLIP vision
tower is a STUB per the assignment: input_specs() supplies 576 precomputed
patch embeddings (ViT-L/14 @ 336px) that overwrite the sequence prefix.
Full attention -> long_500k cell skipped (see DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    frontend="vision",
    frontend_tokens=576,
    act="silu",
    gated_mlp=True,
    norm="rms",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=512,
                          frontend_tokens=4, remat=False)
