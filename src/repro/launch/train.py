"""End-to-end trainer.

The same loop drives CPU smoke runs (mesh 1x1) and pod-scale runs (mesh
16x16 / 2x16x16) — only the mesh shape and batch change.  Demonstrates the
full production path: deterministic data pipeline -> pjit'd train step
(optionally microbatched + int8-compressed DP grads + the paper's
quantized BW-GEMM path) -> heartbeat/straggler monitor -> atomic
checkpoints -> resume.

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
        --steps 40 --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
        --steps 20 --quant-planes 3 --grad-compress
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_config
from repro.launch import mesh as meshlib
from repro.parallel import sharding as sh
from repro.train import checkpoint as ckpt
from repro.train import data as datalib
from repro.train import fault
from repro.train import optimizer as opt
from repro.train import steps as st

__all__ = ["train", "main"]


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          global_batch: int = 8, seq_len: int = 128,
          mesh_shape=(1, 1), lr: float = 3e-4, schedule: str = "cosine",
          quant_planes: int = 0, quant_spec=None,
          grad_compress: bool = False,
          microbatches: int = 1, ckpt_dir: str | None = None,
          ckpt_every: int = 20, resume: bool = False, seed: int = 0,
          log_every: int = 10, overrides: dict | None = None) -> dict:
    from repro.engine import QuantSpec, spec_from_flags
    cfg = get_config(arch, smoke=smoke, **(overrides or {}))
    # resolve the quantized-GEMM spec eagerly: the jit'd step closes over
    # it via cfg (quant_spec may be a QuantSpec or a CLI "k=v,..." string;
    # quant_planes alone is sugar for the trainable jnp oracle engine)
    if not isinstance(quant_spec, QuantSpec):
        quant_spec = spec_from_flags(quant_spec, quant_planes,
                                     quant_impl="planes")
    if quant_spec is not None:
        cfg = cfg.replace(quant=quant_spec, quant_planes=quant_spec.planes)
    ocfg = opt.OptConfig(peak_lr=lr, total_steps=steps,
                         warmup_steps=max(steps // 10, 1),
                         schedule=schedule,
                         moment_dtype=cfg.opt_state_dtype)
    mesh = meshlib.make_mesh(mesh_shape, ("data", "model"))
    rules = sh.default_rules(
        fsdp=cfg.fsdp and mesh.shape["data"] > 1,
        shard_kv_heads=cfg.n_kv_heads >= mesh.shape["model"])

    dcfg = datalib.DataConfig(
        vocab_size=cfg.vocab_size, global_batch=global_batch,
        seq_len=seq_len, seed=seed,
        frontend_tokens=cfg.frontend_tokens if cfg.frontend else 0,
        d_model=cfg.d_model)
    stream = datalib.SyntheticStream(dcfg)

    with sh.mesh_context(mesh, rules):
        state = st.init_train_state(jax.random.PRNGKey(seed), cfg, ocfg,
                                    grad_compress)
        start = 0
        if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            (state, data_state), manifest = ckpt.restore_checkpoint(
                ckpt_dir, (state, stream.state_dict()))
            stream = datalib.SyntheticStream.from_state(dcfg, data_state)
            start = int(manifest["meta"]["train_step"])
            print(f"[train] resumed from step {start}")

        step_fn = jax.jit(st.make_train_step(
            cfg, ocfg, grad_compress=grad_compress,
            microbatches=microbatches), donate_argnums=(0,))

        mon = fault.HeartbeatMonitor(["host0"])
        losses = []
        for i in range(start, steps):
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            mon.record("host0", i, dt)
            losses.append(loss)
            if i % log_every == 0 or i == steps - 1:
                print(f"[train] step {i:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f} ms")
            if ckpt_dir and (i + 1) % ckpt_every == 0:
                path = ckpt.save_checkpoint(
                    ckpt_dir, i + 1, (state, stream.state_dict()),
                    meta={"train_step": i + 1, "arch": arch,
                          "mesh": list(mesh_shape)})
                print(f"[train] checkpoint -> {path}")
        rep = mon.report()
        return {"arch": arch, "steps": steps, "final_loss": losses[-1],
                "first_loss": losses[0], "losses": losses,
                "median_step_s": rep.fleet_median_s}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", choices=["cosine", "wsd", "constant"],
                    default="cosine")
    ap.add_argument("--quant-planes", type=int, default=0)
    ap.add_argument("--quant-spec", default=None,
                    help="full quantized-GEMM spec, e.g. "
                         "'planes=3,encoding=ent,impl=planes'")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                schedule=args.schedule, quant_planes=args.quant_planes,
                quant_spec=args.quant_spec,
                grad_compress=args.grad_compress,
                microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, resume=args.resume,
                seed=args.seed)
    print(json.dumps({k: v for k, v in out.items() if k != "losses"},
                     indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
