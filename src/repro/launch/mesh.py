"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.
"""
from __future__ import annotations

from typing import Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "require_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 ('data','model') or 2-pod 2x16x16
    ('pod','data','model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic restarts pass the recomputed shape)."""
    return jax.make_mesh(shape, axes)


def require_devices(n: int) -> None:
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} devices but only {have} present. For the "
            f"dry-run set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} BEFORE importing jax (launch/dryrun.py does this).")
