"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "require_devices",
           "parse_mesh_shape"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 ('data','model') or 2-pod 2x16x16
    ('pod','data','model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic restarts pass the recomputed shape)."""
    need = 1
    for size in shape:
        need *= int(size)
    require_devices(need, shape=shape, axes=axes)
    return jax.make_mesh(tuple(int(s) for s in shape), tuple(axes))


def parse_mesh_shape(text: str) -> Tuple[int, ...]:
    """Parse a CLI mesh-shape literal like ``'4x2'`` into ``(4, 2)``.

    Axis order is the mesh-construction order: ``data x model`` for the
    2-axis meshes the sharded GEMM path uses.
    """
    try:
        shape = tuple(int(part) for part in text.lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh shape {text!r} is not of the form "
                         f"'DxM' (e.g. '4x2')") from None
    if not shape or any(s < 1 for s in shape):
        raise ValueError(f"mesh shape {text!r} needs positive axis sizes")
    return shape


def require_devices(n: int, *, shape: Optional[Tuple[int, ...]] = None,
                    axes: Optional[Tuple[str, ...]] = None) -> None:
    """Fail fast when the requested mesh cannot be built.

    ``n`` is the device count the caller needs.  When ``shape``/``axes``
    are given, also check that the shape's product matches ``n`` and —
    if the host is short on devices — name the first axis whose size the
    remaining device pool cannot factor, instead of only the total.
    """
    have = len(jax.devices())
    if shape is not None:
        need = 1
        for size in shape:
            need *= int(size)
        if need != n:
            raise ValueError(
                f"mesh shape {tuple(shape)} has {need} devices but "
                f"{n} were requested — the axis product must match")
        if need > have:
            names = tuple(axes) if axes is not None else \
                tuple(f"axis{i}" for i in range(len(shape)))
            remaining = have
            for name, size in zip(names, shape):
                if size > remaining or remaining % size:
                    raise RuntimeError(
                        f"mesh axis {name!r} (size {size}) does not fit: "
                        f"{remaining} of {have} present devices remain for "
                        f"it (mesh shape {tuple(shape)} needs {need}). For "
                        f"CPU testing set XLA_FLAGS="
                        f"--xla_force_host_platform_device_count={need} "
                        f"BEFORE importing jax (launch/dryrun.py does "
                        f"this).")
                remaining //= size
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} devices but only {have} present. For the "
            f"dry-run set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} BEFORE importing jax (launch/dryrun.py does this).")
