import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder host devices to
# build the production meshes.  (Smoke tests / benches import repro without
# this module and see 1 device.)
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, lower + compile the step the
cell's kind dictates (train_step / prefill_step / serve_step) against
ShapeDtypeStruct stand-ins on the production mesh:

    single-pod:  16 x 16          ('data', 'model')     = 256 chips
    multi-pod :  2 x 16 x 16      ('pod', 'data', 'model') = 512 chips

and record memory_analysis() (fits/doesn't), cost_analysis() (FLOPs/bytes
for the roofline), and the collective-op byte census parsed from the
optimized HLO.

Usage:
    python -m repro.launch.dryrun --arch minicpm-2b --shape train_4k \
        --mesh both --out results/minicpm-2b.train_4k.json
    python -m repro.launch.dryrun --all --out-dir results/dryrun
"""
import argparse
import json
import subprocess
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import (ARCHS, get_config, get_shape,
                                    cell_is_runnable, SHAPES)
from repro.launch import mesh as meshlib
from repro.launch import roofline as rl
from repro.obs import trace as obs_trace
from repro.parallel import sharding as sh
from repro.train import optimizer as opt
from repro.train import steps as st

__all__ = ["run_cell", "main"]


def _attach(tree_specs, tree_shardings):
    """ShapeDtypeStructs + NamedShardings -> sharded ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, sd: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sd),
        tree_specs, tree_shardings)


def _rules_for(cfg, multi_pod: bool, mesh, global_batch: int,
               seq_axis: Optional[str] = None,
               capacity_axis: Optional[str] = None,
               shard_kv: Optional[bool] = None,
               kv_seq_axis: Optional[str] = None):
    tp = mesh.shape["model"]
    dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                      if a != "model"]))
    if shard_kv is None:
        # explicit arg shardings must divide evenly
        shard_kv = cfg.n_kv_heads >= tp and cfg.n_kv_heads % tp == 0
    cap = capacity_axis
    if cap == "batch":
        cap = ("pod", "data") if multi_pod else ("data",)
    return sh.default_rules(
        multi_pod=multi_pod,
        fsdp=cfg.fsdp,
        fsdp_over_pod=cfg.fsdp_over_pod,
        shard_kv_heads=shard_kv,
        seq_axis=seq_axis,
        shard_batch=global_batch >= dp and global_batch % dp == 0,
        capacity_axis=cap,
        kv_seq_axis=kv_seq_axis,
    )


def _compile_step(cfg, shape, mesh, rules, multi_pod: bool,
                  microbatches: int = 1):
    """Lower + compile the step a cell's kind dictates.  Returns
    (lowered, compiled)."""
    with sh.mesh_context(mesh, rules):
        if shape.kind == "train":
            ocfg = opt.OptConfig(total_steps=1000,
                                 moment_dtype=cfg.opt_state_dtype)
            state, axes = st.abstract_train_state(cfg, ocfg)
            st_shard = st.train_state_shardings(axes, mesh, rules)
            b_specs = st.batch_specs(cfg, shape.global_batch, shape.seq_len)
            b_shard = st.batch_shardings(cfg, mesh, rules, shape.global_batch)
            step = st.make_train_step(cfg, ocfg, microbatches=microbatches)
            args = (_attach(state, st_shard), _attach(b_specs, b_shard))
            lowered = jax.jit(step, donate_argnums=(0,)).lower(*args)
        elif shape.kind == "prefill":
            state, axes = st.abstract_train_state(
                cfg, opt.OptConfig(moment_dtype=cfg.opt_state_dtype))
            p_shard = st.train_state_shardings(axes, mesh, rules)
            b_specs = st.batch_specs(cfg, shape.global_batch, shape.seq_len)
            b_shard = st.batch_shardings(cfg, mesh, rules, shape.global_batch)
            # prefill runs inference: drop labels from the lowered signature
            b_specs.pop("labels"); b_shard.pop("labels")
            step = st.make_prefill_step(cfg)
            args = (_attach(state.params, p_shard.params),
                    _attach(b_specs, b_shard))
            lowered = jax.jit(step).lower(*args)
        else:  # decode
            state, axes = st.abstract_train_state(
                cfg, opt.OptConfig(moment_dtype=cfg.opt_state_dtype))
            p_shard = st.train_state_shardings(axes, mesh, rules)
            dstate, daxes = st.abstract_decode_state(cfg, shape.global_batch,
                                                     shape.seq_len)
            d_shard = st.decode_state_shardings(daxes, mesh, rules)
            b_shard = st.batch_shardings(cfg, mesh, rules, shape.global_batch)
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                       sharding=b_shard["tokens"])
            pos = jax.ShapeDtypeStruct(
                (shape.global_batch,), jnp.int32,
                sharding=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(
                        *b_shard["tokens"].spec[:1])))
            step = st.make_serve_step(cfg)
            args = (_attach(state.params, p_shard.params), tok, pos,
                    _attach(dstate, d_shard))
            lowered = jax.jit(step, donate_argnums=(3,)).lower(*args)

        compiled = lowered.compile()
    return lowered, compiled


def _cost_tuple(compiled) -> dict:
    """(flops, bytes, collective-bytes, coll-by-op) of a compiled module."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # older jaxlib: list of one dict
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(sum(coll.values())), "coll_by_op": coll,
            "transcendentals": float(cost.get("transcendentals", 0.0))}


def _extrapolate(c1: dict, c2: dict, n_layers: int) -> dict:
    """XLA cost analysis counts a while-loop body ONCE (calibrated on this
    backend), so a scanned-L-layer module under-reports by ~L.  We compile
    depth-1 (scan unrolled trivially) and depth-2 (scan_unroll=2, so both
    iterations appear in the HLO) variants: body = c2 - c1, base = c1 -
    body, total = base + L * body, for each of flops / bytes / collective
    bytes.  Dense (non-chunked) attention is used in the variants so
    softmax-attention FLOPs are not hidden inside inner chunk loops."""
    out = {}
    for k in ("flops", "bytes", "coll", "transcendentals"):
        body = max(c2[k] - c1[k], 0.0)
        base = max(c1[k] - body, 0.0)
        out[k] = base + n_layers * body
    out["coll_by_op"] = {
        op: max(c1["coll_by_op"].get(op, 0)
                + (n_layers - 1) * max(c2["coll_by_op"].get(op, 0)
                                       - c1["coll_by_op"].get(op, 0), 0), 0)
        for op in set(c1["coll_by_op"]) | set(c2["coll_by_op"])}
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               quant_planes: int = 0, seq_axis: Optional[str] = None,
               microbatches: int = 1, remat: Optional[bool] = None,
               capacity_axis: Optional[str] = None,
               shard_kv: Optional[bool] = None,
               kv_seq_axis: Optional[str] = None,
               fsdp: Optional[bool] = None,
               moe_groups: int = 0,
               param_dtype: Optional[str] = None,
               skip_cost_variants: bool = False,
               quant_impl: str = "pallas_fused",
               quant_spec: Optional[str] = None,
               mesh_shape=None):
    """Lower + compile one cell (+ cost variants).  Returns
    (record dict, lowered, compiled).

    mesh_shape: custom (data, model) mesh instead of the production
    16x16 / 2x16x16 (``--mesh DxM``); multi_pod is ignored then.
    """
    from repro.engine import spec_from_flags
    if mesh_shape is not None:
        mesh_name = "x".join(str(s) for s in mesh_shape)
    else:
        mesh_name = "multi" if multi_pod else "single"
    cfg = get_config(arch)
    overrides = {}
    spec = spec_from_flags(quant_spec, quant_planes, quant_impl)
    if spec is not None:
        # bake the spec into the cfg the steps close over (no global
        # switch).  Kernel impls lower each linear under tracing to one
        # int8 dot (what the bw_gemm kernel costs before plane skipping),
        # so cost_analysis reflects the kernelized technique instead of
        # the 4-dot oracle.
        quant_planes = spec.planes
        overrides["quant_planes"] = spec.planes
        overrides["quant"] = spec
    if remat is not None:
        overrides["remat"] = remat
    if fsdp is not None:
        overrides["fsdp"] = fsdp
    if moe_groups:
        overrides["moe_dispatch_groups"] = moe_groups
    if param_dtype:
        overrides["param_dtype"] = param_dtype
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = get_shape(shape_name)
    if not cell_is_runnable(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": mesh_name,
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(full-attention arch; see DESIGN.md)"}, None, None

    if mesh_shape is not None:
        mesh = meshlib.make_mesh(tuple(mesh_shape), ("data", "model"))
        multi_pod = False
    else:
        mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = _rules_for(cfg, multi_pod, mesh, shape.global_batch, seq_axis,
                       capacity_axis, shard_kv, kv_seq_axis)

    # 1) the deliverable compile: full depth, production attention path
    t0 = time.time()
    with obs_trace.span("dryrun.compile", cat="dryrun", arch=arch,
                        shape=shape_name, mesh=mesh_name):
        lowered, compiled = _compile_step(cfg, shape, mesh, rules,
                                          multi_pod, microbatches)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    raw = _cost_tuple(compiled)

    # 2) cost variants: depth 1 / depth 2 (unrolled), dense attention
    n_l = cfg.n_layers
    if skip_cost_variants or n_l <= 2:
        corrected = raw
    else:
        vkw = dict(n_layers=1, attn_chunk=1 << 30)
        if cfg.n_encoder_layers:
            vkw["n_encoder_layers"] = 1
        cfg1 = cfg.replace(**vkw)
        vkw2 = dict(vkw, n_layers=2, scan_unroll=2)
        if cfg.n_encoder_layers:
            vkw2["n_encoder_layers"] = 2
        cfg2 = cfg.replace(**vkw2)
        with obs_trace.span("dryrun.cost_variants", cat="dryrun",
                            arch=arch, shape=shape_name, mesh=mesh_name):
            _, comp1 = _compile_step(cfg1, shape, mesh, rules, multi_pod,
                                     microbatches)
            c1 = _cost_tuple(comp1)
            del comp1
            _, comp2 = _compile_step(cfg2, shape, mesh, rules, multi_pod,
                                     microbatches)
            c2 = _cost_tuple(comp2)
            del comp2
        corrected = _extrapolate(c1, c2, n_l)

    kind = shape.kind
    mfl = rl.model_flops(cfg, shape.global_batch, shape.seq_len, kind)
    roof = rl.roofline_from_compiled(
        {"flops": corrected["flops"], "bytes accessed": corrected["bytes"]},
        "", chips, mfl)
    roof.coll_bytes = corrected["coll"]
    roof.coll_by_op = corrected["coll_by_op"]
    roof.t_collective = corrected["coll"] / rl.ICI_BW
    terms = {"compute": roof.t_compute, "memory": roof.t_memory,
             "collective": roof.t_collective}
    roof.bottleneck = max(terms, key=terms.get)

    def _mem_attr(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok", "kind": kind, "chips": chips,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "quant_planes": quant_planes,
        "quant_impl": spec.impl if spec else None,
        "quant_spec": str(spec) if spec else None,
        "seq_axis": seq_axis,
        "capacity_axis": capacity_axis,
        "kv_seq_axis": kv_seq_axis,
        "fsdp": cfg.fsdp,
        "microbatches": microbatches,
        "remat": cfg.remat,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": _mem_attr("argument_size_in_bytes"),
            "output_bytes": _mem_attr("output_size_in_bytes"),
            "temp_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_bytes": _mem_attr("generated_code_size_in_bytes"),
            "alias_bytes": _mem_attr("alias_size_in_bytes"),
        },
        "cost_raw": raw,
        "cost_corrected": {k: corrected[k] for k in
                           ("flops", "bytes", "coll")},
        "roofline": roof.to_dict(),
        "hlo_collective_count": sum(
            1 for ln in hlo.splitlines()
            if any(f" {op}(" in ln or f" {op}-start(" in ln
                   for op in rl._COLLECTIVE_OPS)),
    }
    return record, lowered, compiled


def run_cell(arch: str, shape_name: str, mesh_kind: str = "both",
             **kw) -> list:
    """mesh_kind: 'single' | 'multi' | 'both' (the production meshes), or
    a custom 'DxM' (data x model) shape literal, e.g. '4x2'."""
    out = []
    kinds = {"single": [False], "multi": [True],
             "both": [False, True]}.get(mesh_kind)
    if kinds is None:
        shape = meshlib.parse_mesh_shape(mesh_kind)
        if len(shape) != 2:
            raise ValueError(f"custom --mesh expects two axes DxM, got "
                             f"{mesh_kind!r}")
        rec, _, _ = lower_cell(arch, shape_name, False, mesh_shape=shape,
                               **kw)
        return [rec]
    for mp in kinds:
        rec, _, _ = lower_cell(arch, shape_name, mp, **kw)
        out.append(rec)
    return out


def _print_record(rec: dict) -> None:
    if rec["status"] != "ok":
        print(f"[dryrun] {rec['arch']} x {rec['shape']} ({rec['mesh']}): "
              f"SKIP - {rec['reason']}")
        return
    r = rec["roofline"]
    m = rec["memory"]
    arg_gb = (m["argument_bytes"] or 0) / 2**30
    tmp_gb = (m["temp_bytes"] or 0) / 2**30
    print(f"[dryrun] {rec['arch']} x {rec['shape']} ({rec['mesh']}, "
          f"{rec['chips']} chips): OK  "
          f"args {arg_gb:.2f} GiB/dev, temps {tmp_gb:.2f} GiB/dev | "
          f"t_comp {r['t_compute_s']:.4f}s t_mem {r['t_memory_s']:.4f}s "
          f"t_coll {r['t_collective_s']:.4f}s -> {r['bottleneck']}-bound, "
          f"useful {100 * r['useful_ratio']:.1f}%, "
          f"roofline {100 * r['roofline_fraction']:.1f}%  "
          f"(compile {rec['t_compile_s']}s)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    help="'single' (16x16), 'multi' (2x16x16), 'both', or "
                         "a custom 'DxM' data x model shape (e.g. 4x2) "
                         "built via launch.mesh.make_mesh")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell in subprocesses")
    ap.add_argument("--quant-spec", default=None,
                    help="full quantized-GEMM spec, e.g. "
                         "'planes=4,encoding=ent,impl=pallas' (the two "
                         "flags below are sugar for its fields)")
    ap.add_argument("--quant-planes", type=int, default=0,
                    help="enable the paper's BW-decomposed int8 path with "
                         "this many EN-T digit planes")
    from repro.engine import IMPLS
    ap.add_argument("--quant-impl", default="pallas_fused", choices=IMPLS,
                    help="quantized matmul engine to lower (kernel impls "
                         "use their cost-representative int8 lowering)")
    ap.add_argument("--seq-axis", default=None,
                    help="mesh axis for sequence parallelism (e.g. 'model')")
    ap.add_argument("--capacity-axis", default=None,
                    help="shard the MoE capacity dim ('batch' = DP axes)")
    ap.add_argument("--kv-seq-axis", default=None,
                    help="shard decode KV caches on the sequence dim "
                         "(e.g. 'model')")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params over the data axis (serving)")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="MoE local-dispatch groups (= DP shard count)")
    ap.add_argument("--param-dtype", default=None,
                    help="override param dtype (e.g. bfloat16 for serving)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable repro.obs tracing and write a Chrome "
                         "trace-event JSON of the lower/compile cells")
    args = ap.parse_args(argv)

    if args.trace:
        obs_trace.enable(clear_events=True)

    if args.all:
        return _run_all(args)

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    recs = run_cell(args.arch, args.shape, args.mesh,
                    quant_planes=args.quant_planes,
                    quant_impl=args.quant_impl,
                    quant_spec=args.quant_spec, seq_axis=args.seq_axis,
                    capacity_axis=args.capacity_axis,
                    kv_seq_axis=args.kv_seq_axis,
                    fsdp=False if args.no_fsdp else None,
                    remat=False if args.no_remat else None,
                    moe_groups=args.moe_groups,
                    param_dtype=args.param_dtype,
                    microbatches=args.microbatches)
    for rec in recs:
        _print_record(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)
    if args.trace:
        obs_trace.save(args.trace)
        print(f"[obs] trace written to {args.trace} "
              f"({len(obs_trace.events())} events)", file=sys.stderr)
    return 0 if all(r["status"] in ("ok", "skipped") for r in recs) else 1


def _run_all(args) -> int:
    """Each cell in its own subprocess: isolates jax state + reclaims RAM."""
    os.makedirs(args.out_dir, exist_ok=True)
    failures = []
    for arch in ARCHS:
        for shape_name in SHAPES:
            out = os.path.join(args.out_dir,
                               f"{arch}.{shape_name}.json")
            if os.path.exists(out):
                print(f"[dryrun] cached: {out}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name,
                   "--mesh", args.mesh, "--out", out]
            if args.quant_planes:
                cmd += ["--quant-planes", str(args.quant_planes),
                        "--quant-impl", args.quant_impl]
            if args.quant_spec:
                cmd += ["--quant-spec", args.quant_spec]
            print(f"[dryrun] {' '.join(cmd[3:])}", flush=True)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append((arch, shape_name))
                print(f"[dryrun] FAILED: {arch} x {shape_name}")
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        return 1
    print("[dryrun] all cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
