"""Roofline-term derivation from a compiled dry-run artifact.

Per the assignment:
    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis()`` supplies HLO FLOPs/bytes.  collective_bytes is parsed
from the optimized HLO text: the summed operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["HW", "Roofline", "collective_bytes", "roofline_from_compiled",
           "model_flops", "quantized_gemm_roofline"]

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  f32[16,128]{1,0}  or  bf16[8,4096,512]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string
    (handles tuple types by summing)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output sizes of collective ops in optimized HLO, by op kind.

    HLO lines look like:
      %ag = bf16[8,512]{1,0} all-gather(%x), replica_groups=...
    The lhs type is the op's (gathered) output; for a byte-moved metric we
    use max(output, sum-of-operand) sizes per instruction, which upper-
    bounds the payload each device injects into the interconnect.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        for op in _COLLECTIVE_OPS:
            # match ' op(' or ' op-start(' but not fusions mentioning it
            if f" {op}(" in s or f" {op}-start(" in s:
                eq = s.split("=", 1)
                if len(eq) != 2:
                    continue
                rhs = eq[1]
                # output type: between '=' and the op name
                head, _, tail = rhs.partition(f" {op}")
                out_bytes = _shape_bytes(head)
                # operand types appear at the call site inside the parens
                opnd_bytes = _shape_bytes(tail.split("(", 1)[-1]
                                          .split("),", 1)[0])
                out[op] += max(out_bytes, opnd_bytes)
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_by_op: Dict[str, int]
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs, both per-chip (cost_analysis reports the
        per-device SPMD program; calibrated 2*M*N*K per dot on this backend).
        > 1 means the 6*N*D estimate exceeds compiled compute (e.g. enc-dec
        archs whose N is embedding-dominated); < 1 flags remat/redundancy."""
        if not self.flops:
            return 0.0
        return (self.model_flops / self.chips) / self.flops

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak compute achievable at the modeled bound:
        (model-useful compute time) / (dominant term)."""
        if self.t_bound <= 0:
            return 0.0
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        return t_useful / self.t_bound

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.coll_bytes,
            "coll_by_op": self.coll_by_op, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_from_compiled(cost: dict, hlo_text: str, chips: int,
                           model_fl: float = 0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    cb = float(sum(coll.values()))
    # cost_analysis flops/bytes are per-device program totals under SPMD
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = cb / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops, byts, cb, coll, chips, t_comp, t_mem, t_coll,
                    bottleneck, model_fl)


def quantized_gemm_roofline(cost: dict, chips: int = 1) -> dict:
    """Roofline terms for a quantized kernel GEMM from its schedule-aware
    ``GemmEngine.cost`` dict (see repro.engine.registry).

    The compute term prices the integer MACs *actually executed* — the
    cost model scales them by measured plane-block density, so digit-plane
    sparsity the sparse dispatch elides shows up as a shorter compute
    term, not merely a predicated-away MXU pass.  The memory term prices
    the DMA block traffic the BlockSpecs imply (the dense kernels move
    every digit plane of every block; the compacted schedule moves only
    scheduled planes) plus any epilogue accumulator round-trip already
    folded into ``dma_bytes``.
    """
    t_comp = 2.0 * cost["int_macs"] / (chips * PEAK_FLOPS)
    t_mem = cost["dma_bytes"] / (chips * HBM_BW)
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        # the pipelined kernels overlap the schedule walk's DMA with the
        # MXU pass (double-buffered prefetch), so pricing the bound as
        # max(compute, memory) — the roofline's usual assumption — is
        # *achievable* there, not optimistic; b_dma_elided B copies were
        # already subtracted from dma_bytes by the cost model.
        "bottleneck": "compute" if t_comp >= t_mem else "memory",
        "grid_steps": cost.get("grid_steps", 0),
        "dma_bytes": cost["dma_bytes"],
        "int_macs": cost["int_macs"],
        "b_dma_elided": cost.get("b_dma_elided", 0),
    }


def model_flops(cfg, global_batch: int, seq_len: int,
                kind: str = "train") -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); D = tokens.

    train: fwd+bwd = 6ND.  prefill: 2ND.  decode: 2N per token * batch.
    """
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n * global_batch * seq_len
    if kind == "decode":
        return 2.0 * n * global_batch            # one token per sequence
    raise ValueError(kind)
