"""Render the dry-run / hillclimb JSON records into the EXPERIMENTS.md
roofline tables.

Usage:
    PYTHONPATH=src python -m repro.launch.report results/dryrun
    PYTHONPATH=src python -m repro.launch.report results/dryrun --md
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import List


def load_records(path: str) -> List[dict]:
    recs = []
    files = [path] if path.endswith(".json") else \
        sorted(glob.glob(os.path.join(path, "*.json")))
    for f in files:
        data = json.load(open(f))
        recs.extend(data if isinstance(data, list) else [data])
    return recs


def one_line(rec: dict, md: bool = False) -> str:
    sep = " | " if md else "  "
    lead = "| " if md else ""
    tail = " |" if md else ""
    if rec["status"] != "ok":
        cells = [rec["arch"], rec["shape"], rec.get("mesh", "?"), "SKIP",
                 rec.get("reason", "")[:46], "", "", "", "", "", ""]
        return lead + sep.join(str(c) for c in cells) + tail
    r = rec["roofline"]
    m = rec["memory"]
    args_gb = (m["argument_bytes"] or 0) / 2**30
    tmp_gb = (m["temp_bytes"] or 0) / 2**30
    cells = [
        rec["arch"], rec["shape"], rec["mesh"], rec["kind"],
        f"{r['t_compute_s']:.4f}", f"{r['t_memory_s']:.4f}",
        f"{r['t_collective_s']:.4f}", r["bottleneck"],
        f"{r['useful_ratio']:.2f}", f"{100 * r['roofline_fraction']:.2f}%",
        f"{args_gb:.1f}/{tmp_gb:.1f}",
    ]
    return lead + sep.join(str(c) for c in cells) + tail


HEADER = ["arch", "shape", "mesh", "kind", "t_comp(s)", "t_mem(s)",
          "t_coll(s)", "bound", "useful", "roofline", "arg/tmp GiB"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    args = ap.parse_args(argv)
    recs = load_records(args.path)
    if args.mesh != "both":
        recs = [r for r in recs if r.get("mesh", args.mesh) == args.mesh]
    recs.sort(key=lambda r: (r["shape"], r["arch"], r.get("mesh", "")))
    if args.md:
        print("| " + " | ".join(HEADER) + " |")
        print("|" + "---|" * len(HEADER))
    else:
        print("  ".join(HEADER))
    for rec in recs:
        print(one_line(rec, args.md))
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skipped")
    print(f"\n{ok} ok, {skip} skipped, {len(recs) - ok - skip} failed",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
