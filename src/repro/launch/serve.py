"""Batched serving launcher: continuous greedy decoding over a request
queue with a fixed-batch engine — the production shape of the decode_32k
dry-run cells, runnable at CPU smoke scale.

The engine keeps `batch` concurrent slots; finished sequences (EOS or
max_tokens) are swapped for queued requests between steps (continuous
batching at step granularity).  The same serve_step the dry-run lowers is
used unchanged.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch granite-34b \
        --requests 12 --batch 4 --max-tokens 24
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.engine import QuantSpec, engine_names, spec_from_flags
from repro.models import layers as L
from repro.models.api import get_api
from repro.parallel.sharding import unbox
from repro.train.steps import make_serve_step

__all__ = ["ServeEngine", "Request", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-batch continuous-batching engine over the decode state.

    quant: a repro.engine.QuantSpec, a legacy layers.QuantState, or None
    (None defers to cfg: an explicit cfg.quant spec, else the quant_planes
    sugar).  The resolved spec is baked into this engine's cfg, so the
    jit'd serve step closes over it — engines with different specs coexist
    in one process without interfering.

    With a kernel impl ("pallas" / "pallas_fused") the engine serves
    through the kernel execution path: every dense weight is pre-planned
    once at init (encode -> digit planes -> occupancy mask ->
    magnitude-ordered channel permutation) and the plan records are
    attached to the param tree, so the jit'd serve step scans/slices them
    like any other parameter and each quantized matmul executes the Pallas
    bw_gemm kernel (interpret mode off-TPU) instead of the jnp oracle.
    """

    def __init__(self, cfg, batch: int, max_len: int, seed: int = 0,
                 quant=None):
        if isinstance(quant, QuantSpec):
            spec = quant if quant.enabled else None
        elif isinstance(quant, L.QuantState):
            spec = quant.spec()
        elif quant is None:
            spec = cfg.quant_spec()
        else:
            raise TypeError(f"quant must be a QuantSpec, QuantState or "
                            f"None; got {type(quant).__name__}")
        self.spec = spec
        # QuantState view kept for stats compatibility (plan_stats etc.)
        self.quant = quant if isinstance(quant, L.QuantState) else \
            L.QuantState(planes=spec.planes if spec else 0,
                         impl=spec.impl if spec else "planes")
        # bake the spec into the cfg the step closes over: no global state
        cfg = cfg.replace(quant=spec,
                          quant_planes=spec.planes if spec else 0)
        self.cfg = cfg
        self.api = get_api(cfg)
        self.batch = batch
        self.max_len = max_len
        self.params = unbox(self.api.init(jax.random.PRNGKey(seed), cfg))
        self.state = unbox(self.api.init_decode(cfg, batch, max_len))
        self._kernel_path = spec is not None and \
            spec.impl in ("pallas", "pallas_fused")
        if self._kernel_path:
            # one-time planning step: encode every dense weight into digit
            # planes + occupancy mask + channel permutation and attach the
            # plan records to the param tree.  The jit'd serve step then
            # scans/slices them like any other parameter and every quantized
            # matmul executes the Pallas kernel.
            from repro.kernels import ops
            self.params, planned = ops.plan_params(self.params, spec)
            self.quant.plan_stats = {"planned_weights": planned,
                                     **ops.plan_cache_stats()}
        self.step = jax.jit(make_serve_step(cfg))
        self.slots: List[Optional[Request]] = [None] * batch
        self.pos = np.zeros(batch, np.int32)
        self.cur = np.zeros((batch, 1), np.int32)
        self.prompt_cursor = np.zeros(batch, np.int32)
        self.steps = 0

    def _admit(self, queue: deque) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and queue:
                req = queue.popleft()
                self.slots[i] = req
                self.pos[i] = 0
                self.prompt_cursor[i] = 0
                self.cur[i, 0] = req.prompt[0]

    def _advance(self, next_tokens: np.ndarray) -> List[Request]:
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            c = int(self.prompt_cursor[i]) + 1
            if c < len(req.prompt):
                # still teacher-forcing the prompt
                self.prompt_cursor[i] = c
                self.cur[i, 0] = req.prompt[c]
            else:
                tok = int(next_tokens[i, 0])
                req.out.append(tok)
                self.cur[i, 0] = tok
                if len(req.out) >= req.max_tokens or \
                        self.pos[i] >= self.max_len - 1:
                    req.done = True
                    finished.append(req)
                    self.slots[i] = None
        return finished

    def run(self, requests: List[Request]) -> dict:
        # the jit'd step closed over this engine's cfg (and its baked-in
        # QuantSpec) at construction: no global impl state to save/restore,
        # and concurrent engines with different specs cannot interfere
        queue = deque(requests)
        done: List[Request] = []
        t0 = time.time()
        while queue or any(s is not None for s in self.slots):
            self._admit(queue)
            nxt, self.state = self.step(
                self.params, jnp.asarray(self.cur),
                jnp.asarray(self.pos), self.state)
            done.extend(self._advance(np.asarray(nxt)))
            self.steps += 1
        dt = time.time() - t0
        gen = sum(len(r.out) for r in done)
        stats = {"requests": len(done), "generated_tokens": gen,
                 "engine_steps": self.steps, "wall_s": round(dt, 2),
                 "tok_per_s": round(gen / max(dt, 1e-9), 1),
                 "quant_spec": str(self.spec) if self.spec else None,
                 "quant_planes": self.spec.planes if self.spec else 0,
                 "quant_impl": self.spec.impl if self.spec else None}
        if self._kernel_path:
            from repro.kernels import ops
            stats["plan_cache"] = ops.plan_cache_stats()
        return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="granite-34b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant-spec", default=None,
                    help="full quantized-GEMM spec, e.g. "
                         "'planes=4,encoding=ent,impl=pallas_fused' "
                         "(the flags below are sugar for its fields)")
    ap.add_argument("--quant-planes", type=int, default=0,
                    help="serve through the BW-decomposed int8 path with "
                         "this many digit planes")
    ap.add_argument("--quant-impl", choices=engine_names(),
                    default="pallas_fused",
                    help="quantized matmul engine (pallas_fused = the "
                         "fused kernel execution path)")
    ap.add_argument("--quant-encoding", default="ent",
                    help="bit-weight encoding (see core.encodings)")
    ap.add_argument("--quant-bits", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).tolist(),
                    args.max_tokens) for i in range(args.requests)]
    spec = spec_from_flags(args.quant_spec, args.quant_planes,
                           args.quant_impl, args.quant_encoding,
                           args.quant_bits)
    eng = ServeEngine(cfg, args.batch,
                      args.prompt_len + args.max_tokens + 1, quant=spec)
    stats = eng.run(reqs)
    print(stats)
    assert stats["requests"] == args.requests
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
