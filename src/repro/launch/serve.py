"""Serving launcher: a thin CLI over the ``repro.serving`` package.

Single-engine mode (default, the historical surface):

    PYTHONPATH=src python -m repro.launch.serve --arch granite-34b \
        --requests 12 --batch 4 --max-tokens 24

Async multi-tier mode (``--tiers N`` or repeated ``--tier name=spec``):
one continuous-batching worker per QuantSpec tier, requests routed by a
cost-model-driven policy, served under a synthetic arrival process:

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b \
        --requests 12 --tiers 2 --arrival poisson --rate 50 --router slo

Crash-recoverable serving: ``--journal`` write-ahead-logs admissions and
committed tokens; after a crash (e.g. the ``crash_server`` chaos fault)
the same command plus ``--resume`` replays the journal, skips requests
it proves complete, and re-enters in-flight ones at their last
committed token:

    PYTHONPATH=src python -m repro.launch.serve --tiers 2 \
        --journal serve.wal --chaos crash_server@s40; \
    PYTHONPATH=src python -m repro.launch.serve --tiers 2 \
        --journal serve.wal --resume --outputs out.json

``ServeEngine`` and ``Request`` remain importable from this module for
backward compatibility; the engine itself now lives in
``repro.serving.engine`` (see README "Serving").
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.engine import QuantSpec, engine_names, spec_from_flags
from repro.serving import (AsyncServer, BrownoutPolicy, DONE,
                           FAILOVER_MODES, Request, RequestJournal,
                           ROUTER_POLICIES, ServeEngine, Tier,
                           default_tiers, loadgen, replay_journal,
                           resume_split, validate_summary)
from repro.serving.scheduler import POLICIES

__all__ = ["ServeEngine", "Request", "main"]


def _parse_tier(text: str) -> Tier:
    """``name=<quant-spec-string>`` (spec ``off`` -> unquantized tier)."""
    if "=" not in text:
        raise argparse.ArgumentTypeError(
            f"--tier expects name=<quant-spec>, got {text!r}")
    name, spec_text = text.split("=", 1)
    return Tier(name.strip(), QuantSpec.parse(spec_text))


def _parse_slack(text):
    try:
        lo, hi = (float(s) for s in text.split(":"))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--deadline-slack expects lo:hi seconds, got {text!r}")
    return (lo, hi)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="granite-34b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant-spec", default=None,
                    help="full quantized-GEMM spec, e.g. "
                         "'planes=4,encoding=ent,impl=pallas_fused' "
                         "(the flags below are sugar for its fields)")
    ap.add_argument("--quant-planes", type=int, default=0,
                    help="serve through the BW-decomposed int8 path with "
                         "this many digit planes")
    ap.add_argument("--quant-impl", choices=engine_names(),
                    default="pallas_fused",
                    help="quantized matmul engine (pallas_fused = the "
                         "fused kernel execution path)")
    ap.add_argument("--quant-encoding", default="ent",
                    help="bit-weight encoding (see core.encodings)")
    ap.add_argument("--quant-bits", type=int, default=8)
    # -- async multi-tier server ------------------------------------------
    ap.add_argument("--tiers", type=int, default=0,
                    help="run the async server with the first N default "
                         "quant tiers (fast/balanced/quality ladder); "
                         "0 = single-engine mode")
    ap.add_argument("--tier", action="append", dest="custom_tiers",
                    type=_parse_tier, metavar="NAME=SPEC",
                    help="custom tier (repeatable), e.g. "
                         "fast=planes=2,impl=pallas_fused; implies the "
                         "async server")
    ap.add_argument("--policy", choices=tuple(POLICIES), default="fcfs",
                    help="admission policy of each tier worker's queue")
    ap.add_argument("--router", choices=ROUTER_POLICIES, default="slo",
                    help="tier-routing policy (cost-model driven)")
    ap.add_argument("--arrival", choices=loadgen.ARRIVAL_PATTERNS,
                    default="none", help="synthetic arrival process")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="arrival rate (req/s) for poisson/uniform")
    ap.add_argument("--deadline-slack", type=_parse_slack, default=None,
                    metavar="LO:HI",
                    help="give each request a deadline of arrival + "
                         "U(lo, hi) seconds (drives --policy deadline "
                         "and --router slo)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="device mesh shape 'data x model' (e.g. 4x2) the "
                         "tier weights are sharded over; feeds the tier "
                         "cost models' device-count axis (collective-bytes "
                         "term) so SLO routing understands sharded tiers")
    ap.add_argument("--realtime", action="store_true",
                    help="threaded wall-clock mode (default: deterministic "
                         "virtual-time simulation)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="arm a fault plan for the run (FaultPlan.parse "
                         "grammar, e.g. 'kill:fast@s3'); equivalent to "
                         "setting REPRO_CHAOS but scoped to this server")
    ap.add_argument("--failover", choices=FAILOVER_MODES,
                    default="restore",
                    help="what a drained request keeps when its tier "
                         "worker dies: 'restore' snapshots decode state "
                         "and migrates committed tokens (bit-exact on a "
                         "same-spec tier), 'restart' regenerates from "
                         "the prompt (the legacy lossy path)")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="write-ahead request journal (JSONL): "
                         "admissions + committed tokens, flushed per "
                         "record, so a crashed run can restart with "
                         "--resume without losing generated tokens")
    ap.add_argument("--resume", action="store_true",
                    help="replay --journal before serving: requests it "
                         "proves complete are not re-served, in-flight "
                         "ones re-enter at their last committed token")
    ap.add_argument("--outputs", default=None, metavar="PATH",
                    help="write {rid: generated tokens} JSON of every "
                         "completed request (including journal-replayed "
                         "completions under --resume)")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="restarts granted per request after a tier "
                         "worker dies (0 = lose its in-flight requests)")
    ap.add_argument("--retry-backoff", type=float, default=0.0,
                    help="base seconds before a drained request is "
                         "re-routed (doubles per retry; 0 = immediate)")
    ap.add_argument("--brownout", default=None, metavar="[ENTER:EXIT]",
                    nargs="?", const="48:12",
                    help="enable graceful degradation: above ENTER backlog "
                         "tokens per slot the router demotes requests down "
                         "the quality ladder, recovering below EXIT "
                         "(default 48:12)")
    ap.add_argument("--step-time-scale", type=float, default=5e4,
                    help="virtual-mode multiplier on the hwmodel step-time "
                         "estimates (smoke models are tiny, so unscaled "
                         "estimates serve any load without queueing; the "
                         "default creates visible contention at smoke "
                         "scale)")
    ap.add_argument("--json", action="store_true",
                    help="print stats as JSON")
    ap.add_argument("--out", default=None,
                    help="also write the stats JSON to this file")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable repro.obs tracing and write a Chrome "
                         "trace-event JSON (chrome://tracing / Perfetto) "
                         "of the run to PATH")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the repro.obs metrics-registry snapshot "
                         "JSON to PATH after the run")
    args = ap.parse_args(argv)
    if args.resume and not args.journal:
        ap.error("--resume requires --journal PATH")

    from repro import obs
    if args.trace:
        obs.enable(clear_events=True)

    cfg = get_config(args.arch, smoke=args.smoke)
    max_len = args.prompt_len + args.max_tokens + 1
    # --batch sets the decode-slot count of every tier worker too
    tiers = tuple(dataclasses.replace(t, batch=args.batch)
                  for t in args.custom_tiers or ()) or \
        (default_tiers(args.tiers, batch=args.batch) if args.tiers else None)
    if args.mesh is not None:
        from repro.launch.mesh import parse_mesh_shape
        shape = parse_mesh_shape(args.mesh)
        if len(shape) != 2:
            ap.error(f"--mesh expects two axes DxM, got {args.mesh!r}")
        if tiers is None:
            print(f"--mesh {args.mesh} ignored in single-engine mode "
                  f"(use --tiers/--tier)", file=sys.stderr)
        else:
            tiers = tuple(dataclasses.replace(t, shards=shape)
                          for t in tiers)

    if tiers is None:
        # -- single-engine mode (the historical surface) -------------------
        if args.journal or args.outputs:
            print("--journal/--resume/--outputs ignored in single-engine "
                  "mode (use --tiers/--tier)", file=sys.stderr)
        rng = np.random.default_rng(args.seed)
        reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).tolist(),
                        args.max_tokens) for i in range(args.requests)]
        spec = spec_from_flags(args.quant_spec, args.quant_planes,
                               args.quant_impl, args.quant_encoding,
                               args.quant_bits)
        eng = ServeEngine(cfg, args.batch, max_len, quant=spec)
        stats = eng.run(reqs, policy=args.policy)
        ok = stats["requests"] == args.requests
        if not ok:
            print(f"serve FAILED: completed {stats['requests']} of "
                  f"{args.requests} requests", file=sys.stderr)
    else:
        # -- async multi-tier mode -----------------------------------------
        reqs = loadgen.synthesize(
            cfg.vocab_size, args.requests,
            prompt_len=(max(args.prompt_len // 2, 1), args.prompt_len),
            max_tokens=(max(args.max_tokens // 2, 1), args.max_tokens),
            pattern=args.arrival, rate=args.rate,
            deadline_slack=args.deadline_slack, seed=args.seed)
        brownout = None
        if args.brownout is not None:
            try:
                enter_s, exit_s = args.brownout.split(":")
                brownout = BrownoutPolicy(enter=float(enter_s),
                                          exit=float(exit_s))
            except ValueError as e:
                ap.error(f"--brownout expects ENTER:EXIT pressures "
                         f"({e})")
        # -- journal / resume (crash recovery) -----------------------------
        journal, replayed = None, {}
        if args.resume:
            rep = replay_journal(args.journal)
            if rep.seed != args.seed:
                ap.error(f"--resume: journal was written with seed "
                         f"{rep.seed}, this run regenerates the load "
                         f"with seed {args.seed}")
            reqs, replayed = resume_split(rep, reqs)
            journal = RequestJournal(args.journal, resume=True,
                                     seed=args.seed)
            journal.seed_from(rep)
            print(f"[journal] replayed {rep.records} record(s) "
                  f"({rep.truncated} truncated): "
                  f"{len(replayed)} complete, "
                  f"{sum(1 for r in reqs if r.out)} in flight, "
                  f"{len(reqs)} to serve", file=sys.stderr)
        elif args.journal:
            try:
                journal = RequestJournal(args.journal, seed=args.seed)
            except FileExistsError as e:
                ap.error(str(e))

        server = AsyncServer(cfg, tiers=tiers, max_len=max_len,
                             seed=args.seed, admission=args.policy,
                             router=args.router,
                             step_time_scale=args.step_time_scale,
                             chaos=args.chaos,
                             retry_budget=args.retry_budget,
                             retry_backoff=args.retry_backoff,
                             brownout=brownout,
                             failover=args.failover, journal=journal)
        from repro.chaos import ServerCrashed
        try:
            stats = server.run(reqs, realtime=args.realtime)
        except ServerCrashed as e:
            if journal is not None:
                journal.close()
                print(f"serve CRASHED: {e} — journal flushed to "
                      f"{args.journal}; restart with --resume to keep "
                      f"committed tokens", file=sys.stderr)
            else:
                print(f"serve CRASHED: {e} (no --journal: in-flight "
                      f"work is lost)", file=sys.stderr)
            return 1
        finally:
            if journal is not None:
                journal.close()
        validate_summary(stats)
        if args.outputs:
            outs = dict(replayed)
            outs.update({r.rid: list(r.out) for r in reqs
                         if r.state == DONE})
            with open(args.outputs, "w") as f:
                json.dump({str(k): v for k, v in sorted(outs.items())},
                          f, indent=1)
        # requests lost to an exhausted retry budget (or total tier loss)
        # are a failure even though they are accounted as rejected — the
        # chaos-smoke CI probe with --retry-budget 0 relies on exit 1;
        # journal-replayed completions count toward the resumed total
        ok = (stats["completed"] + stats["rejected"] + len(replayed)
              == args.requests
              and stats["completed"] + len(replayed) > 0
              and stats["failover"]["lost"] == 0)
        if not ok:
            print(f"serve FAILED: {stats['completed']} completed + "
                  f"{stats['rejected']} rejected + {len(replayed)} "
                  f"replayed of {args.requests} requests "
                  f"({stats['failover']['lost']} lost to failover)",
                  file=sys.stderr)

    print(json.dumps(stats, indent=1, default=str) if args.json else stats)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(stats, f, indent=1, default=str)
    if args.trace:
        obs.save(args.trace)
        print(f"[obs] trace written to {args.trace} "
              f"({len(obs.trace_events())} events)", file=sys.stderr)
    if args.metrics:
        with open(args.metrics, "w") as f:
            json.dump(obs.snapshot(), f, indent=1)
        print(f"[obs] metrics snapshot written to {args.metrics}",
              file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
