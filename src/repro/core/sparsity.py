"""Partial-product sparsity statistics and the column-synchronisation model.

Reproduces:
  * Table II  -- NumPPs census over the INT8 range per encoding.
  * Table III -- average NumPPs of N(0, sigma) matrices after symmetric int8
                 quantisation (scale-invariant, hence near-constant in sigma).
  * Eq. (7)/(8) -- the expected synchronisation interval E[T_sync] of the
                 column-synchronous sparse PE array (OPT3/OPT4), including the
                 paper's ResNet-18 worked example: K=576, s=0.38, M_P=32
                 -> E[T_sync] ~= 381 cycles (~33.84% saving).
"""
from __future__ import annotations

from collections import Counter

import numpy as np
from scipy.stats import binom

from . import encodings as enc

__all__ = [
    "numpp_census",
    "avg_num_pps",
    "quantize_normal_matrix",
    "table3_row",
    "encoded_zero_digit_fraction",
    "tsync_cdf",
    "expected_tsync",
    "tsync_saving",
    "resnet18_example",
]


def numpp_census(encoding: str, bits: int = 8) -> dict:
    """Histogram of NumPPs over the full signed range (paper Table II)."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
    v = np.arange(lo, hi)
    n = enc.num_pps_np(v, encoding, bits)
    return dict(sorted(Counter(n.tolist()).items()))


def avg_num_pps(x_int: np.ndarray, encoding: str, bits: int = 8) -> float:
    """Average number of non-zero PPs per element of an integer matrix."""
    return float(enc.num_pps_np(x_int, encoding, bits).mean())


def quantize_normal_matrix(sigma: float, shape=(1024, 1024), seed: int = 0,
                           bits: int = 8) -> np.ndarray:
    """Sample N(0, sigma) and symmetric-per-tensor quantise to `bits` ints."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, sigma, size=shape)
    qmax = (1 << (bits - 1)) - 1
    scale = np.abs(x).max() / qmax
    return np.clip(np.round(x / scale), -qmax - 1, qmax).astype(np.int64)


def table3_row(encoding: str, sigmas=(0.5, 1.0, 2.5, 5.0), shape=(1024, 1024),
               seed: int = 0) -> list:
    """One row of Table III: avg NumPPs for N(0, sigma) quantised matrices.

    For the sign-magnitude bit-serial row the sign bit is processed as one
    additional partial product per operand (this reproduces the paper's
    bit-serial(M) ~= 3.52 alongside popcount(|x|) ~= 2.51 for normal data).
    """
    extra = 1.0 if encoding == "bitserial_sm" else 0.0
    return [round(extra + avg_num_pps(quantize_normal_matrix(s, shape, seed),
                                      encoding), 2)
            for s in sigmas]


def encoded_zero_digit_fraction(x_int: np.ndarray, encoding: str,
                                bits: int = 8) -> float:
    """The encoding sparsity `s`: fraction of zero digits after encoding.

    This is the `s` that parameterises the T_sync model (Sec. IV-C): each of
    the K*BW digit slots of a dot product is zero with probability s.
    """
    d = enc.encode_np(x_int, encoding, bits)
    return float((d == 0).mean())


# ---------------------------------------------------------------------------
# Eq. (7)/(8): expected synchronisation interval of column-parallel PEs
# ---------------------------------------------------------------------------

def tsync_cdf(k: int, s: float, m_p: int) -> np.ndarray:
    """F(t) = P(T_sync <= t) for t = 0..k.  T_i ~ Binomial(k, 1-s) iid over
    the M_P columns; T_sync = max_i T_i  (paper Eq. (7))."""
    t = np.arange(0, k + 1)
    per_col = binom.cdf(t, k, 1.0 - s)
    return per_col ** m_p


def expected_tsync(k: int, s: float, m_p: int) -> float:
    """E[T_sync] = K - sum_{t=1}^{K-1} F(t)   (paper Eq. (8))."""
    f = tsync_cdf(k, s, m_p)
    return float(k - f[1:k].sum())


def tsync_saving(k: int, s: float, m_p: int) -> float:
    """Fractional cycle saving vs the dense K-cycle reduction."""
    return 1.0 - expected_tsync(k, s, m_p) / k


def resnet18_example() -> dict:
    """The paper's worked example: ResNet-18 middle layer, K = 192*3*3 = 576,
    EN-T weight encoding sparsity s = 0.38, M_P = 32 columns."""
    k, s, m_p = 576, 0.38, 32
    e = expected_tsync(k, s, m_p)
    return {"K": k, "s": s, "M_P": m_p,
            "expected_tsync": e, "saving": 1.0 - e / k}
