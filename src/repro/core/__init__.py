"""Core library: the paper's contribution as composable JAX/NumPy modules.

  encodings  -- MBE / EN-T / bit-serial bit-weight encodings (exact)
  bw_ref     -- BW-decomposed GEMM references (Eq. 4-6) + carry-save semantics
  quant      -- symmetric int8 quantisation + STE (the model-facing path)
  notation   -- executable fine-grained TPE notation, OPT1..OPT4E schedules
  sparsity   -- NumPPs statistics (Tables II/III) and T_sync model (Eq. 7/8)
  hwmodel    -- SMIC-28nm cost model (Tables I/V/VII, Fig. 9)
  simulate   -- workload-level equal-area simulator (Figs. 11-14)
"""
from . import encodings, bw_ref, quant, notation, sparsity, hwmodel, simulate

__all__ = ["encodings", "bw_ref", "quant", "notation", "sparsity",
           "hwmodel", "simulate"]
