"""Reference (pure jax.numpy / NumPy) implementations of the paper's
bit-weight decomposed matrix multiplication (Eq. (1)-(6)) and of the
carry-save ("half_reduce") accumulation semantics of OPT1.

These are the numerical oracles for the Pallas kernels in repro.kernels and
for the executable-notation interpreter in repro.core.notation.

Eq. (4):   C[m,n] = sum_k sum_bw SubA[m,k,bw] * B[k,n]
Eq. (5):   C[m,n] = sum_bw shift(bw) * sum_k map(B[k,n], encode(A[m,k,bw]))
Eq. (6):   the map() is a one-hot selection (mux) over candidate PPs.

All paths are bit-exact against a plain int32 matmul for int8 operands.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import encodings as enc

__all__ = [
    "bw_matmul_np",
    "bw_matmul_jnp",
    "bw_matmul_onehot_np",
    "compress_3_2",
    "compress_4_2",
    "half_reduce",
    "carry_save_matmul_np",
]


# ---------------------------------------------------------------------------
# Eq. (4)/(5): BW-decomposed matmul
# ---------------------------------------------------------------------------

def bw_matmul_np(a: np.ndarray, b: np.ndarray, encoding: str = "ent",
                 bits: int = 8) -> np.ndarray:
    """C = A @ B via the BW decomposition; exact int32 result.

    a: int [M, K], b: int [K, N].  The shift is applied *after* the K
    reduction (the OPT2 "reduction under the same bit-weight" ordering).
    """
    digits = enc.encode_np(a, encoding, bits)          # [M, K, BW]
    weights = enc.digit_weights(encoding, bits)        # [BW]
    acc = np.zeros((a.shape[0], b.shape[1]), dtype=np.int64)
    for bw in range(digits.shape[-1]):
        pp = digits[..., bw].astype(np.int64) @ b.astype(np.int64)  # [M, N]
        acc += pp * weights[bw]                        # deferred shift
    return acc.astype(np.int32)


def bw_matmul_jnp(a, b, encoding: str = "ent", bits: int = 8):
    """jnp version of :func:`bw_matmul_np` (int32 exact)."""
    digits = enc.encode_jnp(a, encoding, bits)         # [M, K, BW]
    weights = jnp.asarray(enc.digit_weights(encoding, bits), dtype=jnp.int32)
    bw_n = digits.shape[-1]
    acc = jnp.zeros((a.shape[0], b.shape[1]), dtype=jnp.int32)
    bi = b.astype(jnp.int32)
    for bw in range(bw_n):
        pp = digits[..., bw].astype(jnp.int32) @ bi
        acc = acc + pp * weights[bw]
    return acc


def bw_matmul_onehot_np(a: np.ndarray, b: np.ndarray, encoding: str = "ent",
                        bits: int = 8) -> np.ndarray:
    """Eq. (6): the mux-selection form.

    The encoded digit selects one of the candidate partial products
    {-2B, -B, 0, B, 2B} via a one-hot vector; the selection is expressed as a
    dot product (enc_vec <> cand_pps), mirroring the CPPG + Mux hardware.
    Only meaningful for radix-4 encodings (digit set {-2..2}).
    """
    assert encoding in ("mbe", "ent")
    digits = enc.encode_np(a, encoding, bits)                  # [M, K, BW]
    weights = enc.digit_weights(encoding, bits)
    bl = b.astype(np.int64)
    # candidate PPs per (k, n): stack of d*B for d in -2..2  -> [5, K, N]
    cand = np.stack([d * bl for d in range(-2, 3)], axis=0)
    onehot = np.eye(5, dtype=np.int64)[digits.astype(np.int64) + 2]  # [M,K,BW,5]
    acc = np.zeros((a.shape[0], b.shape[1]), dtype=np.int64)
    for bw in range(digits.shape[-1]):
        sel = onehot[:, :, bw, :]                              # [M, K, 5]
        # mux: PP[m,k,n] = sum_d sel[m,k,d] * cand[d,k,n]
        pp = np.einsum("mkd,dkn->mn", sel, cand)
        acc += pp * weights[bw]
    return acc.astype(np.int32)


# ---------------------------------------------------------------------------
# OPT1: carry-save ("half_reduce") accumulation semantics
# ---------------------------------------------------------------------------
# A 3:2 compressor (carry-save adder) maps three operands to a (sum, carry)
# pair such that a+b+c == sum + carry, with no carry *propagation* (each bit
# position is independent -> delay independent of bit-width; paper Table V).
# On two's complement machine integers the bitwise identity is
#     sum   = a ^ b ^ c
#     carry = ((a&b) | (a&c) | (b&c)) << 1
# which holds exactly in modular (wrap-around) arithmetic.

def compress_3_2(a, b, c, xp=np):
    """3:2 compressor on integer arrays: returns (sum, carry), a+b+c == s+c."""
    s = xp.bitwise_xor(xp.bitwise_xor(a, b), c)
    cy = xp.left_shift(
        xp.bitwise_or(xp.bitwise_or(xp.bitwise_and(a, b), xp.bitwise_and(a, c)),
                      xp.bitwise_and(b, c)),
        1,
    )
    return s, cy


def compress_4_2(a, b, c, d, xp=np):
    """4:2 compressor built from two 3:2 stages: a+b+c+d == s + cy."""
    s1, c1 = compress_3_2(a, b, c, xp)
    s2, c2 = compress_3_2(s1, c1, d, xp)
    return s2, c2


def half_reduce(terms, xp=np):
    """Paper primitive ``half_reduce``: reduce n terms to a redundant
    (sum, carry) pair using a compressor tree.  sum+carry == sum(terms)."""
    terms = list(terms)
    if not terms:
        raise ValueError("half_reduce needs at least one term")
    zero = terms[0] * 0
    s, c = terms[0], zero
    for t in terms[1:]:
        s, c = compress_3_2(s, c, t, xp)
    return s, c


def carry_save_matmul_np(a: np.ndarray, b: np.ndarray, encoding: str = "ent",
                         bits: int = 8) -> np.ndarray:
    """OPT1 semantics: K-dimension reduction kept in (acc_s, acc_c) redundant
    form; the single full 'add' happens only after the loop (in the paper this
    final add lives in the SIMD vector core outside the PE array)."""
    digits = enc.encode_np(a, encoding, bits).astype(np.int64)   # [M, K, BW]
    weights = enc.digit_weights(encoding, bits)
    m_dim, k_dim = a.shape
    n_dim = b.shape[1]
    bl = b.astype(np.int64)
    acc_s = np.zeros((m_dim, n_dim), dtype=np.int64)
    acc_c = np.zeros((m_dim, n_dim), dtype=np.int64)
    for k in range(k_dim):
        # the per-(m,k) product expressed as a sum of shifted PPs
        pp = np.zeros((m_dim, n_dim), dtype=np.int64)
        for bw in range(digits.shape[-1]):
            pp += digits[:, k, bw:bw + 1] * bl[k][None, :] * weights[bw]
        # half_reduce(acc_s, acc_c, pp) -> redundant accumulation, no carry
        # propagation inside the loop.
        acc_s, acc_c = compress_3_2(acc_s, acc_c, pp, np)
    return (acc_s + acc_c).astype(np.int32)   # the deferred full "add"
