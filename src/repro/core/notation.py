"""Executable fine-grained TPE notation (paper Sec. III).

The paper's first contribution is a compute-centric notation that exposes the
bit-weight (BW) dimension of MACs and represents the reduction logic
explicitly through hardware primitives:

    encode / sparse / map / shift / half_reduce / add / accumulate / sync

This module makes that notation *executable and checkable*:

  * :class:`Schedule` describes where each primitive lives in the loop nest
    (which loops are spatial vs temporal, whether BW is spatial or temporal,
    whether the reduction is a full accumulate or a redundant half_reduce,
    whether sparse skipping of encoded digits is enabled, and whether the
    encoder is shared across a PE column).

  * :func:`validate` enforces the legality rules derived in Sec. III-B:
      - ``map`` must remain in the innermost position (non-commutative mux);
      - ``shift`` may move outside K (it is independent of N and K) but must
        stay inside/at the BW loop;
      - ``encode`` is independent of N and may be hoisted above N_P;
      - ``half_reduce`` must sit at the reduction level it reduces;
      - a spatial BW loop cannot be reordered outside K without being made
        temporal first (OPT2's transformation).

  * :func:`execute` interprets a schedule on real integer matrices and
    returns the exact GEMM result together with cycle/occupancy statistics,
    so every OPT variant is verified against ``A @ B`` bit-exactly.

  * :func:`component_census` counts the hardware component instances implied
    by a schedule for a given array geometry -- the input to the area/energy
    model in :mod:`repro.core.hwmodel`.

The six schedules of the paper are provided: BASELINE (TPU-like parallel
MAC), OPT1, OPT2, OPT3, OPT4C, OPT4E.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from . import encodings as enc
from .bw_ref import compress_3_2

__all__ = [
    "Schedule", "ArrayGeometry", "SCHEDULES", "validate", "execute",
    "component_census", "ExecResult",
]


# ---------------------------------------------------------------------------
# Schedule description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """Placement/ordering choices for the MAC micro-architecture."""
    name: str
    # BW handling: "spatial" (parallel PP lanes inside the PE, classic MAC)
    # or "temporal" (BW iterated in time, OPT2+) -- Sec. IV-B.
    bw: str = "spatial"
    # Reduction: "accumulate" (full adder + accumulator inside PE) or
    # "half_reduce" (redundant carry-save pair, deferred add) -- Sec. IV-A.
    reduction: str = "accumulate"
    # Shift placement: "pe" (a shifter per PP lane inside the PE) or "simd"
    # (single deferred shift outside the array) -- Sec. IV-B.
    shift_at: str = "pe"
    # Sparse skipping of zero *encoded digits* (not raw bit-slices) -- Sec. IV-C.
    sparse: bool = False
    # Encoder shared per PE column (hoisted above N_P) -- Sec. IV-D.
    shared_encoder: bool = False
    # PEs per group sharing one compressor tree + output DFFs (OPT4E).
    group: int = 1
    # Operand encoding for PP generation.
    encoding: str = "ent"

    @property
    def deferred_add(self) -> bool:
        return self.reduction == "half_reduce"


@dataclasses.dataclass(frozen=True)
class ArrayGeometry:
    """PE array geometry: M_P columns x N_P rows, K_P unrolled operands."""
    m_p: int = 32
    n_p: int = 32
    k_p: int = 4


SCHEDULES: Dict[str, Schedule] = {
    "baseline": Schedule("baseline"),
    "opt1": Schedule("opt1", reduction="half_reduce"),
    "opt2": Schedule("opt2", bw="temporal", reduction="half_reduce",
                     shift_at="simd"),
    "opt3": Schedule("opt3", bw="temporal", reduction="half_reduce",
                     shift_at="simd", sparse=True),
    "opt4c": Schedule("opt4c", bw="temporal", reduction="half_reduce",
                      shift_at="simd", sparse=True, shared_encoder=True),
    "opt4e": Schedule("opt4e", bw="temporal", reduction="half_reduce",
                      shift_at="simd", sparse=True, shared_encoder=True,
                      group=4),
}


# ---------------------------------------------------------------------------
# Legality (Sec. III-B)
# ---------------------------------------------------------------------------

def validate(s: Schedule) -> List[str]:
    """Return a list of legality violations (empty == legal)."""
    errs = []
    if s.bw not in ("spatial", "temporal"):
        errs.append(f"bw must be spatial|temporal, got {s.bw}")
    if s.reduction not in ("accumulate", "half_reduce"):
        errs.append(f"reduction must be accumulate|half_reduce")
    if s.shift_at not in ("pe", "simd"):
        errs.append("shift must live in the PE or the SIMD core")
    # Deferring the shift to the SIMD core requires every PP accumulated in a
    # PE to carry the *same* bit-weight, i.e. BW must be a temporal loop
    # outside K (Sec. IV-B: "keep the shift within the BW loop").
    if s.shift_at == "simd" and s.bw != "temporal":
        errs.append("shift can only be deferred if BW is temporalised "
                    "(a spatial-BW PE mixes bit-weights in one cycle)")
    # Sparse skipping serialises the encoded digits in time; with a spatial
    # BW the zero PP lanes still occupy hardware, so skipping needs
    # temporal BW (Sec. IV-C).
    if s.sparse and s.bw != "temporal":
        errs.append("sparse digit skipping requires temporal BW")
    # The encoder can be hoisted above N_P because encode() is independent of
    # N (Eq. (6)); but sharing it across the column only removes work if the
    # PEs consume *encoded* digits serially, i.e. sparse mode.
    if s.shared_encoder and not s.sparse:
        errs.append("shared encoder requires the sparse serial PP stream")
    # Deferring the accumulate's final add is only correct when the in-loop
    # reduction is associative over the redundant pair -- i.e. half_reduce.
    if s.group > 1 and not s.sparse:
        errs.append("PE grouping shares one compressor among serial PP "
                    "lanes; requires sparse mode")
    # map() is always innermost by construction in execute(); nothing to check.
    return errs


# ---------------------------------------------------------------------------
# Execution (exact semantics + cycle statistics)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecResult:
    c: np.ndarray                   # exact GEMM result (int64)
    cycles: int                     # total array cycles (with sync stalls)
    busy_cycles: np.ndarray         # per-column busy cycles
    sync_events: int
    pp_processed: int               # non-zero PPs actually processed
    pp_total: int                   # K * BW digit slots

    @property
    def utilization(self) -> float:
        return float(self.busy_cycles.mean() / max(self.cycles, 1))


def _digit_planes(a: np.ndarray, s: Schedule) -> Tuple[np.ndarray, np.ndarray]:
    d = enc.encode_np(a, s.encoding)                   # [M, K, BW]
    w = enc.digit_weights(s.encoding)
    return d.astype(np.int64), w.astype(np.int64)


def execute(s: Schedule, a: np.ndarray, b: np.ndarray,
            geom: ArrayGeometry = ArrayGeometry(4, 4, 2)) -> ExecResult:
    """Interpret the schedule on int matrices a [M,K], b [K,N].

    The interpreter mirrors the paper's loop nests (Figs. 5-8): output tiles
    of M_P x N_P are produced by the PE array; K is consumed K_P operands per
    cycle (dense) or one non-zero encoded digit per cycle per PE lane
    (sparse), with column-level synchronisation.
    """
    errs = validate(s)
    if errs:
        raise ValueError(f"illegal schedule {s.name}: {errs}")
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    digits, weights = _digit_planes(a, s)              # [M,K,BW], [BW]
    bw_n = digits.shape[-1]

    c = np.zeros((m, n), dtype=np.int64)
    busy = np.zeros(geom.m_p, dtype=np.int64)
    total_cycles = 0
    sync_events = 0
    pp_proc = 0

    # --- dense schedules: every (k, bw) slot costs a cycle slice -----------
    if not s.sparse:
        if s.bw == "spatial":
            # classic MAC: BW lanes in parallel, one k per cycle per PE.
            # acc kept either in an accumulator or a redundant pair.
            for mt0 in range(0, m, geom.m_p):
                for nt0 in range(0, n, geom.n_p):
                    ms = slice(mt0, min(mt0 + geom.m_p, m))
                    ns = slice(nt0, min(nt0 + geom.n_p, n))
                    acc_s = np.zeros((ms.stop - ms.start, ns.stop - ns.start),
                                     dtype=np.int64)
                    acc_c = np.zeros_like(acc_s)
                    for kk in range(k):
                        pp = np.zeros_like(acc_s)
                        for bw in range(bw_n):   # spatial PP lanes
                            pp += (digits[ms, kk, bw:bw + 1]
                                   * b[kk][None, ns] * weights[bw])
                        if s.deferred_add:
                            acc_s, acc_c = compress_3_2(acc_s, acc_c, pp, np)
                        else:
                            acc_s = acc_s + pp     # full add + accumulate
                    c[ms, ns] = acc_s + acc_c
                    cyc = k
                    total_cycles += cyc
                    busy += cyc
                    pp_proc += (ms.stop - ms.start) * k * bw_n
        else:
            # OPT2: BW temporal outer loop; K split into K_P (spatial) x K_T.
            for mt0 in range(0, m, geom.m_p):
                for nt0 in range(0, n, geom.n_p):
                    ms = slice(mt0, min(mt0 + geom.m_p, m))
                    ns = slice(nt0, min(nt0 + geom.n_p, n))
                    out = np.zeros((ms.stop - ms.start, ns.stop - ns.start),
                                   dtype=np.int64)
                    for bw in range(bw_n):
                        acc_s = np.zeros_like(out)
                        acc_c = np.zeros_like(out)
                        for kt0 in range(0, k, geom.k_p):
                            kp = slice(kt0, min(kt0 + geom.k_p, k))
                            # K_P PPs of identical bit-weight: no shifters.
                            pp = digits[ms, kp, bw] @ b[kp][:, ns]
                            acc_s, acc_c = compress_3_2(acc_s, acc_c, pp, np)
                        # deferred single shift + add in the SIMD core
                        out += (acc_s + acc_c) * weights[bw]
                    c[ms, ns] = out
                    cyc = bw_n * ((k + geom.k_p - 1) // geom.k_p)
                    total_cycles += cyc
                    busy += cyc
                    pp_proc += (ms.stop - ms.start) * k * bw_n
        return ExecResult(c, total_cycles, busy, sync_events, pp_proc,
                          m * k * bw_n)

    # --- sparse schedules (OPT3/OPT4): skip zero encoded digits ------------
    # Columns of the PE array share the multiplicand A (one matrix row per
    # column); each column serially consumes the non-zero (k, bw) digit
    # pairs, `group` digits per cycle (OPT4E).  Columns synchronise after
    # each K_T block (here: after each full K reduction).
    for mt0 in range(0, m, geom.m_p):
        rows = range(mt0, min(mt0 + geom.m_p, m))
        for nt0 in range(0, n, geom.n_p):
            ns = slice(nt0, min(nt0 + geom.n_p, n))
            col_cycles = np.zeros(geom.m_p, dtype=np.int64)
            for ci, mm in enumerate(rows):
                nz_k, nz_bw = np.nonzero(digits[mm])   # sparse() primitive
                npp = len(nz_k)
                pp_proc += npp * 1
                # serial PP accumulation through a 3-2 compressor
                acc_s = np.zeros(ns.stop - ns.start, dtype=np.int64)
                acc_c = np.zeros_like(acc_s)
                for kk, bw in zip(nz_k, nz_bw):
                    pp = digits[mm, kk, bw] * b[kk, ns] * weights[bw]
                    acc_s, acc_c = compress_3_2(acc_s, acc_c, pp, np)
                c[mm, ns] = acc_s + acc_c
                col_cycles[ci] = -(-npp // s.group)    # ceil(npp / group)
            t_sync = int(col_cycles.max()) if len(list(rows)) else 0
            total_cycles += t_sync                     # sync() barrier
            busy += col_cycles
            sync_events += 1
    return ExecResult(c, total_cycles, busy, sync_events, pp_proc,
                      m * k * bw_n)


# ---------------------------------------------------------------------------
# Component census (feeds the area/energy model)
# ---------------------------------------------------------------------------

def component_census(s: Schedule, geom: ArrayGeometry,
                     acc_bits: int = 32, op_bits: int = 8) -> Dict[str, float]:
    """Hardware component instances implied by a schedule, per PE array.

    Counts follow Figs. 5-8: e.g. OPT1 removes the per-PE full adder and
    accumulator in favour of one 4-2 compressor tree plus ~M_P*N_P/K SIMD
    adders outside the array; OPT4 hoists encoders out of the PEs entirely.
    Widths are attached so the cost model can price each instance.
    """
    n_pe = geom.m_p * geom.n_p
    bw_n = enc.num_digits(s.encoding)
    pp_bits = 2 * op_bits               # product width before accumulation
    census: Dict[str, float] = {}

    def add(name, count, width):
        census[f"{name}@{width}"] = census.get(f"{name}@{width}", 0) + count

    if s.bw == "spatial":
        # classic parallel MAC front end: BW encoder+CPPG+mux+shifter lanes.
        add("encoder", n_pe * bw_n, 3)
        add("cppg_mux", n_pe * bw_n, op_bits)
        add("shifter", n_pe * bw_n, pp_bits)
        if s.reduction == "accumulate":
            add("compressor", n_pe, pp_bits)            # PP tree only
            add("full_adder", n_pe, pp_bits)
            add("accumulator", n_pe, acc_bits)
            add("dff_out", n_pe, acc_bits)
        else:                                           # OPT1
            add("compressor", n_pe, acc_bits)           # tree absorbs acc
            add("dff_out", n_pe, 2 * acc_bits)          # redundant pair
            add("simd_adder", max(1, n_pe // max(geom.k_p, 1)), acc_bits)
        add("dff_in", n_pe, 2 * op_bits)                # A and B operands
        return census

    # temporal-BW designs: PPs in a PE share one bit-weight -> no shifter.
    if not s.sparse:                                    # OPT2
        add("encoder", n_pe * geom.k_p, 3)
        add("cppg_mux", n_pe * geom.k_p, op_bits)
        add("compressor", n_pe, pp_bits + 3)            # K_P-input tree
        add("dff_out", n_pe, 2 * (pp_bits + 3))
        add("dff_in", n_pe, 2 * op_bits * geom.k_p)     # widened input
        add("simd_shifter", max(1, n_pe // max(geom.k_p, 1)), acc_bits)
        add("simd_adder", max(1, n_pe // max(geom.k_p, 1)), acc_bits)
        return census

    # sparse designs
    if not s.shared_encoder:                            # OPT3
        add("encoder", n_pe * geom.k_p, 3)
        add("sparse_encoder", n_pe, bw_n * geom.k_p)
        add("cppg_mux", n_pe, op_bits)
        add("compressor3_2", n_pe, pp_bits)
        add("dff_in", n_pe, 2 * op_bits * geom.k_p)
        add("dff_out", n_pe, 2 * pp_bits)
        add("simd_shifter", max(1, n_pe // max(geom.k_p, 1)), acc_bits)
        add("simd_adder", max(1, n_pe // max(geom.k_p, 1)), acc_bits)
        return census

    # OPT4C / OPT4E: encoder + sparse encoder shared per column (M_P of them)
    add("encoder", geom.m_p * geom.k_p, 3)
    add("sparse_encoder", geom.m_p, bw_n * geom.k_p)
    add("cppg_mux", n_pe, op_bits)
    if s.group == 1:                                    # OPT4C
        add("compressor3_2", n_pe, pp_bits)
        add("dff_out", n_pe, 2 * pp_bits)
        add("dff_in", n_pe, 2 + op_bits)                # sel(2b) + B(8b)
    else:                                               # OPT4E
        n_grp = n_pe // s.group
        add("compressor6_2", n_grp, pp_bits)
        add("dff_out", n_grp, 2 * pp_bits)              # shared DFFs
        add("dff_in", n_pe, 2 + op_bits)
    add("simd_shifter", max(1, n_pe // max(geom.k_p, 1)), acc_bits)
    add("simd_adder", max(1, n_pe // max(geom.k_p, 1)), acc_bits)
    return census
