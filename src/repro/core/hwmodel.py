"""Hardware cost model for the paper's PE micro-architectures.

Encodes the paper's synthesis data (SMIC 28nm-HKCP-RVT, 0.72V):

  * Table I  -- INT8 MAC component decomposition at a 2ns clock.
  * Table V  -- 4-2 compressor tree: delay is *independent of bit-width*
                (the key property behind OPT1).
  * Table VII -- array-level area/power/frequency for the four classic TPE
                architectures, the bit-slice baselines, and OPT1..OPT4E.
  * Fig. 9/14 anchors -- PE-level area scaling vs clock constraint.

Two layers:
  1. a *data* layer holding the published numbers (the reproduction target);
  2. a *model* layer that prices a component census (repro.core.notation)
     with Table I/V entries and predicts PE area -- validated against the
     published PE areas in tests.

All areas um^2, delays ns, power W (arrays) / uW (components), freqs MHz.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = [
    "TABLE1_MAC", "TABLE1_ACC", "TABLE5_COMPRESSOR", "COMPONENTS",
    "component_area", "component_delay", "ArrayDesign", "TABLE7",
    "peak_tops", "area_efficiency", "energy_efficiency", "table7_report",
    "efficiency_ratios", "pe_area_model", "PE_AREA_ANCHORS",
    "PAPER_AVG_PPS_ENT",
]

# Average non-zero PPs per EN-T-encoded INT8 operand on the paper's
# normally-distributed test vectors (Table III / Sec. V-D).  Our own
# measurement gives 2.24; the published array numbers are consistent with
# 2.27, which we keep for the faithful reproduction path.
PAPER_AVG_PPS_ENT = 2.27

# --------------------------- Table I ---------------------------------------
# width -> (area um^2, delay ns, power uW) @ 2ns clock
TABLE1_MAC = {20: (179.30, 1.56, 27.1), 24: (192.65, 1.67, 29.2),
              28: (206.01, 1.84, 31.4), 32: (238.51, 1.97, 36.3)}
TABLE1_ACC = {20: (57.32, 0.80, 8.6), 24: (62.43, 0.90, 9.4),
              28: (82.78, 0.99, 12.3), 32: (95.13, 1.13, 14.3)}
TABLE1_COMPRESSOR_14 = (55.92, 0.31, 8.5)
TABLE1_FULL_ADDER_14 = (51.32, 0.34, 7.7)

# --------------------------- Table V ---------------------------------------
# width -> (area um^2, delay ns): delay flat at ~0.32ns for any width.
TABLE5_COMPRESSOR = {14: (52.92, 0.31), 16: (60.98, 0.32), 20: (77.11, 0.32),
                     24: (93.99, 0.32), 28: (110.12, 0.32), 32: (126.25, 0.32)}


def _interp(table: Dict[int, tuple], width: int, col: int) -> float:
    ws = sorted(table)
    vals = [table[w][col] for w in ws]
    return float(np.interp(width, ws, vals))


# Per-component unit costs used to price a census.  Derived from Tables I/V
# plus standard-cell estimates for the small front-end blocks (the paper does
# not list them separately; values chosen so that the modelled PE areas match
# the published 246 / 81.27 / 311 um^2 anchors -- see tests).
COMPONENTS = {
    # name: (area per instance as fn(width), delay ns fn(width))
    # Front-end unit costs are calibrated so a census-priced MAC matches
    # Table I: MAC@32 (238.5um^2) - compressor(55.9) - FA(51.3) - acc(95.1)
    # leaves ~36um^2 for the whole encode/CPPG/mux/shift front end.
    "encoder":        (lambda w: 2.0,                lambda w: 0.08),
    "sparse_encoder": (lambda w: 2.2 * w,            lambda w: 0.12),
    "cppg_mux":       (lambda w: 0.5 * w,            lambda w: 0.10),
    "shifter":        (lambda w: 0.25 * w,           lambda w: 0.12),
    "compressor":     (lambda w: _interp(TABLE5_COMPRESSOR, w, 0),
                       lambda w: _interp(TABLE5_COMPRESSOR, w, 1)),
    "compressor3_2":  (lambda w: 0.45 * _interp(TABLE5_COMPRESSOR, w, 0),
                       lambda w: 0.29),
    "compressor6_2":  (lambda w: 0.9 * _interp(TABLE5_COMPRESSOR, w, 0),
                       lambda w: 0.40),
    "full_adder":     (lambda w: 51.32 * w / 14.0,   lambda w: 0.34 + 0.056 * (w - 14)),
    "accumulator":    (lambda w: _interp(TABLE1_ACC, w, 0),
                       lambda w: _interp(TABLE1_ACC, w, 1)),
    "dff_in":         (lambda w: 1.1 * w,            lambda w: 0.0),
    "dff_out":        (lambda w: 1.1 * w,            lambda w: 0.0),
    "simd_adder":     (lambda w: 51.32 * w / 14.0,   lambda w: 0.0),  # pipelined, off critical path
    "simd_shifter":   (lambda w: 1.6 * w,            lambda w: 0.0),
}


def component_area(name: str, width: int) -> float:
    return COMPONENTS[name][0](width)


def component_delay(name: str, width: int) -> float:
    return COMPONENTS[name][1](width)


def pe_area_model(census: Dict[str, float], n_pe: int) -> float:
    """Area per PE (um^2) from a census of a whole array.

    simd_* components live in the vector core OUTSIDE the PE array — the
    paper's PE area/power measurements cover "PE input/output DFFs,
    combinational logic, and clock networks" only (Sec. V-A), so they are
    excluded here (they are still counted by the census for honesty)."""
    total = 0.0
    for key, count in census.items():
        name, width = key.rsplit("@", 1)
        if name.startswith("simd_"):
            continue
        total += count * component_area(name, int(width))
    return total / n_pe


# Published single-PE area anchors (um^2): Fig. 14 caption.
PE_AREA_ANCHORS = {"baseline": 246.0, "opt4c": 81.27, "opt4e_group": 311.0}


# --------------------------- Table VII -------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrayDesign:
    name: str
    freq_mhz: float
    area_um2: float
    power_w: float
    n_pe: int = 1024            # PE (or PE-lane) count used for peak perf
    avg_pps: float = 1.0        # serial designs retire 1 PP/cycle/PE
    published_peak_tops: Optional[float] = None
    published_tops_per_w: Optional[float] = None
    published_tops_per_mm2: Optional[float] = None
    family: str = "classic"     # classic | bitslice | ours
    base: Optional[str] = None  # baseline this design is compared against


TABLE7: Dict[str, ArrayDesign] = {d.name: d for d in [
    # -- published baselines (others') --------------------------------------
    ArrayDesign("tpu",       1000, 370631, 0.25, 1024, 1.0, 2.05, 8.05, 5.53),
    ArrayDesign("ascend",    1000, 320783, 0.24, 1024, 1.0, 2.05, 8.21, 7.22),
    ArrayDesign("trapezoid", 1000, 283704, 0.22, 1024, 1.0, 2.05, 9.31, 7.22),
    ArrayDesign("flexflow",  1000, 332848, 0.28, 1024, 1.0, 2.05, 7.29, 6.15),
    ArrayDesign("laconic",   1000, 213248, 1.21, 0,    1.0, 0.81, 0.67, 3.77,
                family="bitslice"),
    ArrayDesign("bitlet",    1000, 415800, 0.23, 0,    1.0, 0.74, 3.29, 1.79,
                family="bitslice"),
    ArrayDesign("sibia",      250, 1069000, 0.10, 0,   1.0, 0.77, 7.65, 0.72,
                family="bitslice"),
    ArrayDesign("bitwave",    250, 861681, 0.01, 0,    1.0, 0.22, 14.77, 0.25,
                family="bitslice"),
    # -- ours ----------------------------------------------------------------
    ArrayDesign("opt1_tpu",       1500, 436646, 0.37, 1024, 1.0,
                family="ours", base="tpu"),
    ArrayDesign("opt1_ascend",    1500, 332185, 0.24, 1024, 1.0,
                family="ours", base="ascend"),
    ArrayDesign("opt1_trapezoid", 1500, 271989, 0.22, 1024, 1.0,
                family="ours", base="trapezoid"),
    ArrayDesign("opt1_flexflow",  1500, 373898, 0.38, 1024, 1.0,
                family="ours", base="flexflow"),
    ArrayDesign("opt2_flexflow",  1500, 347216, 0.35, 1024, 1.0,
                family="ours", base="flexflow"),
    ArrayDesign("opt3",  2000, 460349, 0.70, 1024, PAPER_AVG_PPS_ENT,
                family="ours", base="laconic"),
    ArrayDesign("opt4c", 2500, 259298, 0.51, 1024, PAPER_AVG_PPS_ENT,
                family="ours", base="laconic"),
    ArrayDesign("opt4e", 2000, 672419, 0.89, 4096, PAPER_AVG_PPS_ENT,
                family="ours", base="laconic"),
]}


def peak_tops(d: ArrayDesign) -> float:
    """Peak performance: 2 ops/MAC * N_pe * f / avg PPs-per-MAC."""
    if d.published_peak_tops is not None and d.family != "ours":
        return d.published_peak_tops
    return 2.0 * d.n_pe * d.freq_mhz * 1e6 / d.avg_pps / 1e12


def area_efficiency(d: ArrayDesign) -> float:
    """TOPS / mm^2."""
    return peak_tops(d) / (d.area_um2 * 1e-6)


def energy_efficiency(d: ArrayDesign) -> float:
    """TOPS / W."""
    return peak_tops(d) / d.power_w


def efficiency_ratios() -> Dict[str, Dict[str, float]]:
    """Our designs' improvement factors over their published baselines.

    Reproduces the abstract's headline numbers: area-efficiency x1.27 / x1.28
    / x1.56 / x1.44 for systolic / 3D-Cube / adder-tree / 2D-Matrix, energy
    x1.04 / x1.56 / x1.49 / x1.20, and OPT4E vs Laconic x2.85 area / x12.10
    energy.
    """
    out = {}
    for d in TABLE7.values():
        if d.family != "ours" or d.base is None:
            continue
        b = TABLE7[d.base]
        base_ae = b.published_tops_per_mm2 or area_efficiency(b)
        base_ee = b.published_tops_per_w or energy_efficiency(b)
        out[d.name] = {
            "area_eff": area_efficiency(d) / base_ae,
            "energy_eff": energy_efficiency(d) / base_ee,
        }
    return out


def table7_report() -> List[dict]:
    rows = []
    for d in TABLE7.values():
        rows.append({
            "design": d.name, "freq_mhz": d.freq_mhz,
            "area_um2": d.area_um2, "power_w": d.power_w,
            "peak_tops": round(peak_tops(d), 3),
            "tops_per_mm2": round(area_efficiency(d), 2),
            "tops_per_w": round(energy_efficiency(d), 2),
            "published_tops_per_mm2": d.published_tops_per_mm2,
            "published_tops_per_w": d.published_tops_per_w,
        })
    return rows


# --------------------------- Fig. 9 anchors --------------------------------
# (design -> {freq_ghz: PE area um^2-ish anchors and max usable frequency})
FIG9 = {
    "baseline": {"area": {1.0: 367.0, 1.5: 707.0}, "fmax_ghz": 1.5,
                 "best_ghz": 1.0},
    "opt1":     {"area": {1.0: 380.0, 1.5: 433.0}, "fmax_ghz": 2.0,
                 "best_ghz": 1.5},   # x1.14 growth 1.0 -> 1.5 GHz
    "opt3":     {"area": {1.5: 440.0, 2.0: 480.0}, "fmax_ghz": 2.5,
                 "best_ghz": 2.0},   # x1.09 growth 1.5 -> 2.0 GHz
    "opt4c":    {"area": {2.0: 230.0, 2.5: 253.0}, "fmax_ghz": 3.0,
                 "best_ghz": 2.5},
    "opt4e":    {"area": {1.5: 610.0, 2.0: 657.0}, "fmax_ghz": 2.0,
                 "best_ghz": 2.0},
}


def max_frequency_ghz(design: str) -> float:
    return FIG9[design]["fmax_ghz"]


def area_growth(design: str) -> float:
    """Area growth factor across the design's published frequency step."""
    a = FIG9[design]["area"]
    ks = sorted(a)
    return a[ks[-1]] / a[ks[0]]
