"""Bit-weight (BW) dimension encodings of integer operands.

This module implements the three operand encodings studied by the paper
("Exploring the Performance Improvement of Tensor Processing Engines through
Transformation in the Bit-weight Dimension of MACs"):

  * ``mbe``        -- Modified Booth Encoding, radix-4, digit set {-2..2}.
                      Overlapping 3-bit windows of the two's complement input.
  * ``ent``        -- EN-T encoding [45]: sign-magnitude canonical radix-4
                      recoding.  The magnitude's base-4 digits {0,1,2,3} are
                      recoded with 3 -> -1 + carry (and 4 -> 0 + carry), the
                      sign is then applied to every digit.  This reproduces the
                      paper's Figure 3 examples exactly (91 -> {1,2,-1,-1},
                      124 -> {2,0,-1,0}) and the Table II histogram
                      {4:72, 3:108, 2:60, 1:15, 0:1}.
  * ``bitserial``  -- Radix-2 two's complement bit-serial digits {-1,0,1}
                      (MSB carries weight -2^(n-1)).
  * ``bitserial_sm`` -- Radix-2 sign-magnitude bit-serial (Table III row
                      "bit-serial(M)").

Every encoding satisfies  value == sum_bw digit[bw] * radix**bw  exactly for
all int8 inputs (verified exhaustively in tests).  All functions have a NumPy
and a jax.numpy implementation; the jnp versions are pure element-wise bit
arithmetic and are safe to use inside Pallas kernels.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

__all__ = [
    "ENCODINGS",
    "num_digits",
    "radix",
    "digit_weights",
    "encode_np",
    "encode_jnp",
    "decode_np",
    "decode_jnp",
    "num_pps_np",
    "mbe_digits_np",
    "ent_digits_np",
    "bitserial_digits_np",
    "bitserial_sm_digits_np",
    "mbe_digits_jnp",
    "ent_digits_jnp",
    "bitserial_digits_jnp",
    "bitserial_sm_digits_jnp",
]

ENCODINGS = ("mbe", "ent", "bitserial", "bitserial_sm")

_BITS = 8  # the paper's INT8 setting; generalised via the `bits` argument.


def num_digits(encoding: str, bits: int = _BITS) -> int:
    """Number of BW positions produced by `encoding` for a `bits`-wide input."""
    if encoding in ("mbe", "ent"):
        return (bits + 1) // 2
    if encoding in ("bitserial", "bitserial_sm"):
        return bits
    raise ValueError(f"unknown encoding {encoding!r}")


def radix(encoding: str) -> int:
    if encoding in ("mbe", "ent"):
        return 4
    if encoding in ("bitserial", "bitserial_sm"):
        return 2
    raise ValueError(f"unknown encoding {encoding!r}")


def digit_weights(encoding: str, bits: int = _BITS) -> np.ndarray:
    """Weight of each BW position: radix**bw (LSB first)."""
    r = radix(encoding)
    n = num_digits(encoding, bits)
    return r ** np.arange(n, dtype=np.int64)


# ---------------------------------------------------------------------------
# NumPy implementations
# ---------------------------------------------------------------------------

def mbe_digits_np(x, bits: int = _BITS) -> np.ndarray:
    """Modified Booth digits, LSB first.  d_bw = -2*a[2bw+1] + a[2bw] + a[2bw-1].

    Returns int8 array of shape x.shape + (bits//2,) with digits in {-2..2}.
    """
    x = np.asarray(x)
    u = x.astype(np.int64) & ((1 << bits) - 1)
    n = (bits + 1) // 2
    out = np.empty(x.shape + (n,), dtype=np.int8)
    for bw in range(n):
        a_hi = (u >> (2 * bw + 1)) & 1
        a_mid = (u >> (2 * bw)) & 1
        a_lo = (u >> (2 * bw - 1)) & 1 if bw > 0 else np.zeros_like(u)
        out[..., bw] = (-2 * a_hi + a_mid + a_lo).astype(np.int8)
    return out


def ent_digits_np(x, bits: int = _BITS) -> np.ndarray:
    """EN-T digits, LSB first: sign-magnitude canonical radix-4 recoding."""
    x = np.asarray(x).astype(np.int64)
    sign = np.where(x < 0, -1, 1).astype(np.int64)
    m = np.abs(x)
    n = (bits + 1) // 2
    out = np.empty(x.shape + (n,), dtype=np.int8)
    carry = np.zeros_like(m)
    for bw in range(n):
        t = ((m >> (2 * bw)) & 3) + carry
        d = np.where(t == 3, -1, np.where(t == 4, 0, t))
        carry = (t >= 3).astype(np.int64)
        out[..., bw] = (sign * d).astype(np.int8)
    return out


def bitserial_digits_np(x, bits: int = _BITS) -> np.ndarray:
    """Two's complement radix-2 digits, LSB first; MSB digit is negated."""
    x = np.asarray(x)
    u = x.astype(np.int64) & ((1 << bits) - 1)
    out = np.empty(x.shape + (bits,), dtype=np.int8)
    for bw in range(bits):
        b = (u >> bw) & 1
        out[..., bw] = (-b if bw == bits - 1 else b).astype(np.int8)
    return out


def bitserial_sm_digits_np(x, bits: int = _BITS) -> np.ndarray:
    """Sign-magnitude radix-2 digits (paper Table III "bit-serial(M)")."""
    x = np.asarray(x).astype(np.int64)
    sign = np.where(x < 0, -1, 1).astype(np.int64)
    m = np.abs(x)
    out = np.empty(x.shape + (bits,), dtype=np.int8)
    for bw in range(bits):
        out[..., bw] = (sign * ((m >> bw) & 1)).astype(np.int8)
    return out


_NP_ENCODERS = {
    "mbe": mbe_digits_np,
    "ent": ent_digits_np,
    "bitserial": bitserial_digits_np,
    "bitserial_sm": bitserial_sm_digits_np,
}


def encode_np(x, encoding: str, bits: int = _BITS) -> np.ndarray:
    """Encode integers into BW digits (LSB first) with the chosen encoding."""
    return _NP_ENCODERS[encoding](x, bits)


def decode_np(digits, encoding: str, bits: int = _BITS) -> np.ndarray:
    """Inverse of encode: sum_bw digit[bw] * radix**bw."""
    w = digit_weights(encoding, bits)
    return (np.asarray(digits).astype(np.int64) * w).sum(axis=-1)


def num_pps_np(x, encoding: str, bits: int = _BITS) -> np.ndarray:
    """Number of non-zero partial products per element (paper Sec. II-C)."""
    return (encode_np(x, encoding, bits) != 0).sum(axis=-1)


# ---------------------------------------------------------------------------
# jax.numpy implementations (element-wise bit arithmetic; Pallas-safe)
# ---------------------------------------------------------------------------

def mbe_digits_jnp(x, bits: int = _BITS):
    """MBE digits, stacked on a new trailing axis. int8 in, int8 out."""
    u = x.astype(jnp.int32) & ((1 << bits) - 1)
    n = (bits + 1) // 2
    ds = []
    for bw in range(n):
        a_hi = (u >> (2 * bw + 1)) & 1
        a_mid = (u >> (2 * bw)) & 1
        a_lo = ((u >> (2 * bw - 1)) & 1) if bw > 0 else jnp.zeros_like(u)
        ds.append((-2 * a_hi + a_mid + a_lo).astype(jnp.int8))
    return jnp.stack(ds, axis=-1)


def ent_digits_jnp(x, bits: int = _BITS):
    """EN-T digits (sign-magnitude canonical radix-4), trailing BW axis."""
    xi = x.astype(jnp.int32)
    sign = jnp.where(xi < 0, -1, 1)
    m = jnp.abs(xi)
    n = (bits + 1) // 2
    ds = []
    carry = jnp.zeros_like(m)
    for bw in range(n):
        t = ((m >> (2 * bw)) & 3) + carry
        d = jnp.where(t == 3, -1, jnp.where(t == 4, 0, t))
        carry = (t >= 3).astype(jnp.int32)
        ds.append((sign * d).astype(jnp.int8))
    return jnp.stack(ds, axis=-1)


def bitserial_digits_jnp(x, bits: int = _BITS):
    u = x.astype(jnp.int32) & ((1 << bits) - 1)
    ds = []
    for bw in range(bits):
        b = (u >> bw) & 1
        ds.append((jnp.where(bw == bits - 1, -b, b)).astype(jnp.int8))
    return jnp.stack(ds, axis=-1)


def bitserial_sm_digits_jnp(x, bits: int = _BITS):
    """Sign-magnitude radix-2 digits (Table III "bit-serial(M)"), jnp."""
    xi = x.astype(jnp.int32)
    sign = jnp.where(xi < 0, -1, 1)
    m = jnp.abs(xi)
    ds = []
    for bw in range(bits):
        ds.append((sign * ((m >> bw) & 1)).astype(jnp.int8))
    return jnp.stack(ds, axis=-1)


_JNP_ENCODERS = {
    "mbe": mbe_digits_jnp,
    "ent": ent_digits_jnp,
    "bitserial": bitserial_digits_jnp,
    "bitserial_sm": bitserial_sm_digits_jnp,
}


def encode_jnp(x, encoding: str, bits: int = _BITS):
    return _JNP_ENCODERS[encoding](x, bits)


def decode_jnp(digits, encoding: str, bits: int = _BITS):
    w = jnp.asarray(digit_weights(encoding, bits), dtype=jnp.int32)
    return (digits.astype(jnp.int32) * w).sum(axis=-1)
