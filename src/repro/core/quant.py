"""Symmetric integer quantisation for the BW-GEMM compute path.

The paper's TPE consumes INT8 operands; in the JAX framework the technique
surfaces as a quantised matmul path:   y = (q_x @ q_w) * (s_x * s_w)
where the int8 x int8 -> int32 product is computed by the bit-weight
decomposed kernel (repro.kernels.bw_gemm) on TPU.

Includes a straight-through estimator so the path is trainable (QAT).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "symmetric_scale",
    "quantize",
    "dequantize",
    "fake_quant_ste",
    "quantized_matmul_ref",
    "plane_qmax",
    "quantize_to_planes",
    "quantize_for_spec",
]


def symmetric_scale(x, axis=None, bits: int = 8, eps: float = 1e-8):
    """Per-tensor (axis=None) or per-axis symmetric scale: max|x| / qmax."""
    qmax = float((1 << (bits - 1)) - 1)
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, eps) / qmax


def quantize(x, scale, bits: int = 8):
    """Round-to-nearest symmetric quantisation to a signed `bits` integer."""
    qmax = (1 << (bits - 1)) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


@jax.custom_vjp
def fake_quant_ste(x, scale, bits: int = 8):
    """Quantise-dequantise with a straight-through gradient."""
    return dequantize(quantize(x, scale, bits), scale)


def _fq_fwd(x, scale, bits):
    return fake_quant_ste(x, scale, bits), None


def _fq_bwd(_, g):
    return (g, None, None)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


def plane_qmax(planes: int, radix: int = 4, bits: int = 8) -> int:
    """Largest magnitude whose encoding uses only `planes` low digit planes.

    radix 4 (EN-T / MBE digit set {-2..2}): 2 * (4^p - 1) / 3
        -> {1:2, 2:10, 3:42, 4:170 (clipped to the int range)}.
    radix 2 (bit-serial, digit set {-1,0,1}): 2^p - 1.

    Quantising with this qmax makes the higher planes *structurally* empty
    (in sign-magnitude encodings), so the bw_gemm kernel skips their MXU
    passes entirely: a runtime-selectable effective precision from a single
    int8 representation (the bit-weight dimension as a first-class compute
    axis).
    """
    int_max = (1 << (bits - 1)) - 1
    if radix == 4:
        return min(2 * (4 ** planes - 1) // 3, int_max)
    if radix == 2:
        return min((1 << planes) - 1, int_max)
    raise ValueError(f"unsupported radix {radix}")


def quantize_to_planes(x, planes: int = 4, axis=None, radix: int = 4,
                       bits: int = 8):
    """Symmetric quantisation bounded to `planes` digit planes.

    Returns (q:int8, scale).  With the default radix-4/int8 grid, planes=4
    is ordinary int8; planes=3 trades ~1.6 effective bits for 25% fewer MXU
    passes in bw_gemm; planes=2 is int4-class compute at half the passes.
    """
    qmax = plane_qmax(planes, radix, bits)
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def quantize_for_spec(x, spec, axis=None):
    """quantize_to_planes on the grid a repro.engine.QuantSpec describes."""
    return quantize_to_planes(x, spec.planes, axis=axis, radix=spec.radix,
                              bits=spec.bits)


def quantized_matmul_ref(x, w, bits: int = 8,
                         w_scale_axis: Optional[int] = 0):
    """Reference quantised matmul: int8 activations x int8 weights.

    x: [..., K] float;  w: [K, N] float.
    Per-tensor activation scale, per-output-channel weight scale.
    Returns float32 [..., N].  This is the jnp oracle the Pallas bw_gemm
    kernel path must match (bit-exactly in the integer domain).
    """
    sx = symmetric_scale(x, axis=None, bits=bits)
    sw = symmetric_scale(w, axis=w_scale_axis, bits=bits)      # [1, N]
    qx = quantize(x, sx, bits)
    qw = quantize(w, sw, bits)
    acc = jax.lax.dot_general(
        qx.astype(jnp.int32), qw.astype(jnp.int32),
        (((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (sx * sw.reshape(1, -1))
