"""Workload-level simulator for the sparse column-synchronous TPE
(OPT3/OPT4C/OPT4E) vs a parallel-MAC array -- reproduces the methodology of
the paper's Figs. 11-14 (GPT-2 / MobileNetV3 / ViT workloads, busy/idle
column statistics, equal-area speedup and energy ratios).

The encoded operand is the *weight* matrix (as in the paper's ResNet-18
example); activations are the broadcast multiplier.  A column PE consumes the
non-zero EN-T digits of its weight row serially (`group` digits per cycle for
OPT4E), and columns synchronise after each reduction -- so the time for an
output tile is the max over columns of their non-zero-PP counts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from . import encodings as enc
from . import hwmodel as hw
from .sparsity import quantize_normal_matrix

__all__ = [
    "WorkloadLayer", "WORKLOADS", "ArraySpec", "ARRAYS",
    "simulate_layer", "simulate_workload", "fig14_throughput",
]


@dataclasses.dataclass(frozen=True)
class WorkloadLayer:
    name: str
    m: int        # weight output channels (rows of the encoded operand)
    k: int        # reduction dimension
    n: int = 1    # multiplier batch (1 = single token / pixel, Figs. 11)
    count: int = 1


def _transformer_layers(d: int, d_ff: int, n_layers: int, name: str,
                        kv_mult: float = 1.0) -> List[WorkloadLayer]:
    return [
        WorkloadLayer(f"{name}.qkv", int(d * (1 + 2 * kv_mult)), d, 1, n_layers),
        WorkloadLayer(f"{name}.attn_out", d, d, 1, n_layers),
        WorkloadLayer(f"{name}.mlp_up", d_ff, d, 1, n_layers),
        WorkloadLayer(f"{name}.mlp_down", d, d_ff, 1, n_layers),
    ]


# Representative backbones (paper Figs. 11-13).
WORKLOADS: Dict[str, List[WorkloadLayer]] = {
    # GPT-2 (124M): d=768, ff=3072, 12 layers
    "gpt2": _transformer_layers(768, 3072, 12, "gpt2"),
    # ViT-Base: d=768, ff=3072, 12 layers
    "vit": _transformer_layers(768, 3072, 12, "vit"),
    # MobileViT-S attention + conv blocks (reduced dims, mixed K)
    "mobilevit": (_transformer_layers(144, 288, 4, "mvit.s2") +
                  _transformer_layers(192, 384, 4, "mvit.s3") +
                  [WorkloadLayer("mvit.pw1", 64, 32, 1, 2),
                   WorkloadLayer("mvit.pw2", 128, 64, 1, 2)]),
    # MobileNetV3-Large: depthwise (K=9) + pointwise blocks
    "mobilenetv3": [
        WorkloadLayer("mnv3.dw3x3", 72, 9, 1, 4),       # DW: tiny K
        WorkloadLayer("mnv3.dw5x5", 120, 25, 1, 4),
        WorkloadLayer("mnv3.pw_expand", 240, 80, 1, 4),  # PW: large K
        WorkloadLayer("mnv3.pw_project", 112, 480, 1, 4),
        WorkloadLayer("mnv3.pw_head", 960, 160, 1, 2),
    ],
    # ResNet-18 middle stage (img2col), the Sec. IV-C example: K = 192*3*3
    "resnet18": [WorkloadLayer("res3.conv3x3", 192, 576, 1, 4),
                 WorkloadLayer("res4.conv3x3", 384, 1152, 1, 4)],
    # BERT-Base
    "bert": _transformer_layers(768, 3072, 12, "bert"),
}


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    name: str
    m_p: int            # columns (weight rows processed in parallel)
    n_p: int            # broadcast width (output tile columns)
    group: int          # PP lanes per column cell (OPT4E: 4)
    freq_ghz: float
    area_um2: float
    power_w: float
    serial: bool        # True: cycles = non-zero PP count; False: 1 MAC/cyc


ARRAYS: Dict[str, ArraySpec] = {
    "tpu":   ArraySpec("tpu", 32, 32, 1, 1.0, hw.TABLE7["tpu"].area_um2,
                       hw.TABLE7["tpu"].power_w, serial=False),
    "opt3":  ArraySpec("opt3", 32, 32, 1, 2.0, hw.TABLE7["opt3"].area_um2,
                       hw.TABLE7["opt3"].power_w, serial=True),
    "opt4c": ArraySpec("opt4c", 32, 32, 1, 2.5, hw.TABLE7["opt4c"].area_um2,
                       hw.TABLE7["opt4c"].power_w, serial=True),
    "opt4e": ArraySpec("opt4e", 32, 32, 4, 2.0, hw.TABLE7["opt4e"].area_um2,
                       hw.TABLE7["opt4e"].power_w, serial=True),
}

_NPP_LUT = {e: (enc.encode_np(np.arange(-128, 128), e) != 0).sum(-1)
            for e in ("ent", "mbe")}


def _weight_matrix(m: int, k: int, seed: int) -> np.ndarray:
    """Synthetic normally-distributed int8 weight matrix (paper test data)."""
    return quantize_normal_matrix(1.0, (m, k), seed=seed)


@dataclasses.dataclass
class LayerStats:
    name: str
    cycles: int
    time_us: float
    busy_min: float      # fastest column busy fraction
    busy_max: float      # slowest column busy fraction (== 1 by definition)
    busy_avg: float
    idle_ratio: float
    macs: int


def simulate_layer(layer: WorkloadLayer, spec: ArraySpec, seed: int = 0,
                   encoding: str = "ent",
                   weights: np.ndarray | None = None) -> LayerStats:
    """Cycle count for one layer's matrix-vector product on the array."""
    w = weights if weights is not None else _weight_matrix(layer.m, layer.k, seed)
    n_tiles = -(-layer.n // spec.n_p)
    if not spec.serial:
        # parallel MAC: K cycles per (m-tile, n-tile), all columns dense-busy
        m_tiles = -(-layer.m // spec.m_p)
        cycles = m_tiles * n_tiles * layer.k
        t = cycles / (spec.freq_ghz * 1e9) * 1e6 * layer.count
        return LayerStats(layer.name, cycles * layer.count, t, 1.0, 1.0, 1.0,
                          0.0, layer.m * layer.k * layer.n * layer.count)
    npp = _NPP_LUT[encoding][(w.astype(np.int64) & 0xFF) if False else
                             (w.astype(np.int64) + 128)]
    row_pps = npp.sum(axis=1)                       # serial cycles per row
    col_cycles = -(-row_pps // spec.group)          # ceil: group lanes/cycle
    pad = (-len(col_cycles)) % spec.m_p
    if pad:
        col_cycles = np.concatenate([col_cycles, np.zeros(pad, np.int64)])
    tiles = col_cycles.reshape(-1, spec.m_p)        # [m_tiles, M_P]
    t_sync = tiles.max(axis=1)                      # sync() per tile
    cycles = int(t_sync.sum()) * n_tiles
    busy = tiles / np.maximum(t_sync[:, None], 1)
    t = cycles / (spec.freq_ghz * 1e9) * 1e6 * layer.count
    return LayerStats(layer.name, cycles * layer.count, t,
                      float(busy.min(axis=1).mean()), 1.0,
                      float(busy.mean()), float(1.0 - busy.mean()),
                      layer.m * layer.k * layer.n * layer.count)


def simulate_workload(workload: str | Sequence[WorkloadLayer],
                      spec_name: str = "opt4e", baseline: str = "tpu",
                      seed: int = 0) -> dict:
    """Equal-silicon-area comparison of a sparse TPE vs the parallel-MAC
    baseline on a full backbone (paper Figs. 12/13)."""
    layers = WORKLOADS[workload] if isinstance(workload, str) else list(workload)
    spec, base = ARRAYS[spec_name], ARRAYS[baseline]
    ours = [simulate_layer(l, spec, seed + i) for i, l in enumerate(layers)]
    ref = [simulate_layer(l, base, seed + i) for i, l in enumerate(layers)]
    t_ours = sum(s.time_us for s in ours)
    t_ref = sum(s.time_us for s in ref)
    # equal area: the budget of one baseline array buys area_ref/area_ours
    # copies of ours; work is data-parallel across tiles.
    area_scale = base.area_um2 / spec.area_um2
    speedup = t_ref / (t_ours / area_scale)
    # energy: power * time (per array); ours idles early columns (clock-gated)
    e_ref = base.power_w * t_ref
    busy_avg = float(np.mean([s.busy_avg for s in ours]))
    e_ours = spec.power_w * t_ours * (0.6 + 0.4 * busy_avg)  # gated idle power
    return {
        "workload": workload if isinstance(workload, str) else "custom",
        "design": spec_name,
        "speedup_equal_area": round(float(speedup), 3),
        "energy_ratio": round(float(e_ref / e_ours), 3),
        "busy_avg": round(busy_avg, 4),
        "idle_ratio": round(1 - busy_avg, 4),
        "time_us_ours": round(t_ours, 2),
        "time_us_baseline": round(t_ref, 2),
        "per_layer": ours,
    }


def fig14_throughput(freq_ghz: float = 2.0) -> List[dict]:
    """Fig. 14: throughput and energy/op vs NumPPs at equal area.

    1 parallel MAC (246 um^2) ~ 3 OPT4C PEs (81.27 um^2) ~ 1 OPT4E PE group
    (311 um^2).  MAC throughput is NumPPs-independent; the sparse PEs retire
    one (OPT4C) / four (OPT4E) non-zero PPs per cycle.
    """
    rows = []
    for npps in [1, 2, 2.27, 3, 4]:
        mac = 1.0 * 1e9 * 2            # 1 GHz MAC: 2 ops/cycle
        opt4c3 = 3 * freq_ghz * 1e9 * 2 / npps
        opt4e = 4 * freq_ghz * 1e9 * 2 / npps
        rows.append({
            "num_pps": npps,
            "mac_gops": mac / 1e9,
            "3x_opt4c_gops": round(opt4c3 / 1e9, 2),
            "opt4e_group_gops": round(opt4e / 1e9, 2),
            "speedup_3x_opt4c": round(opt4c3 / mac, 2),
            "speedup_opt4e": round(opt4e / mac, 2),
        })
    return rows
